"""Trace-driven client realism: availability, stragglers, dropout, churn.

The simulation in ``fed/rounds.py`` historically assumed every selected
client responds instantly — exactly the idealization the FL systems
literature flags as the gap between simulations and deployments.  This
module closes it with a **seeded, injectable-clock** fault-injection
layer:

* **Diurnal availability** — each client follows a sinusoidal
  availability curve over a simulated day, with a per-client phase (so
  "time zones" exist); an unavailable client refuses the round.
* **Stragglers** — per-client compute tiers stretch the simulated
  round-trip latency; a straggler past the round deadline is dropped
  from aggregation and the server eats the full deadline wait.
* **Mid-round dropout** — a configurable hazard rate turns exposure
  time into a drop probability; a mid-round dropout disconnects partway
  through its latency and contributes nothing.
* **Population churn** — clients join/leave the population between
  rounds (per-round join/leave probabilities); a departed client
  refuses selection, and the join/leave delta stream is exactly what
  the serving path's ``update_embeddings`` delta buffer ingests.

Everything is a pure function of ``(seed, trace parameters, round
index)``: per-round randomness comes from
``np.random.SeedSequence([seed, stream, round])`` — never from global
RNG state, never from host time — so a fixed ``(seed, trace)`` replays
**bit-identically** and every chaos scenario is a deterministic test.
Simulated time lives in :class:`SimClock`, which doubles as the
injectable clock ``FederatedRunner`` routes its per-phase
``RoundResult.timings`` through.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: integer stream labels feeding np.random.SeedSequence — one
#: independent deterministic stream per failure mode per round.
_STREAMS = {"availability": 1, "latency": 2, "dropout": 3,
            "drop_frac": 4, "churn": 5, "static": 6}


@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """Per-round serving contract the simulated server enforces.

    Args:
        deadline_s: wall-clock budget for the round in simulated
            seconds.  Clients whose latency exceeds it are dropped from
            aggregation and the server waits the full deadline for
            them.  ``None`` = no deadline (today's behavior): the
            server waits for every responding client.
        reward_blend: weight of the deadline-attainment term in the
            DQN reward: ``(1-b)·favor + b·(attainment − 1)`` with
            attainment = completed/selected.  0 keeps the paper's pure
            accuracy shaping.
        straggler_mult: a responding client counts as a straggler when
            its latency exceeds this multiple of the cohort's median
            latency.
    """
    deadline_s: Optional[float] = None
    reward_blend: float = 0.0
    straggler_mult: float = 2.0


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Knobs of the :class:`ClientTrace` failure-mode model.

    Per-client assignments (``phase_assign`` / ``tier_assign`` /
    ``hazard_assign``) are optional: when omitted they are drawn
    deterministically from the trace seed; benchmarks pass explicit
    assignments to correlate failure modes with data heterogeneity
    (e.g. "clients holding labels 5–9 are on the slow tier").
    """
    availability: str = "none"           # "none" | "diurnal"
    day_period_s: float = 240.0          # simulated seconds per "day"
    avail_floor: float = 0.05            # trough availability
    avail_amplitude: float = 0.9         # peak - trough
    phase_assign: Optional[Tuple[float, ...]] = None   # per-client [0,1)
    tiers: Tuple[float, ...] = (1.0,)    # latency stretch per tier
    tier_assign: Optional[Tuple[int, ...]] = None
    base_latency_s: float = 1.0          # tier-1.0 mean round latency
    latency_jitter: float = 0.1          # lognormal sigma on latency
    dropout_hazard: float = 0.0          # drops per simulated second
    hazard_assign: Optional[Tuple[float, ...]] = None  # per-client mult
    p_join: float = 0.0                  # per-round rejoin probability
    p_leave: float = 0.0                 # per-round leave probability


@dataclasses.dataclass
class RoundOutcome:
    """What the simulated server observed for one round's cohort.

    ``completed`` and ``dropped`` partition ``selected`` (asserted by
    the property suite); ``reasons`` breaks the drops down by failure
    mode (``unavailable`` / ``deadline`` / ``dropout``).
    """
    round_idx: int
    selected: np.ndarray                 # (K,) client ids as selected
    completed: np.ndarray                # ids that made aggregation
    dropped: np.ndarray                  # ids that did not
    straggler_ids: np.ndarray            # responders slower than mult×median
    latencies_s: np.ndarray              # (K,) per-selected simulated latency
    elapsed_s: float                     # simulated round wall time
    deadline_s: Optional[float]
    reasons: Dict[str, int]

    @property
    def attainment(self) -> float:
        """Fraction of the cohort that beat the deadline: completed/selected."""
        return len(self.completed) / max(len(self.selected), 1)


class SimClock:
    """Injectable monotonic clock for the simulation.

    Starts at 0.0 and only moves when :meth:`advance` is called — the
    realism layer advances it by each round's simulated wall time, so
    ``RoundResult.timings`` measured through it report *simulated*
    seconds, bit-identical across replays (no host time anywhere).
    Calling the instance reads it, so it is drop-in for
    ``time.perf_counter``.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    __call__ = now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"SimClock.advance: dt={dt} must be >= 0")
        self._now += float(dt)
        return self._now


class ClientTrace:
    """Deterministic per-client failure-mode model over a population.

    All randomness is derived from ``SeedSequence([seed, stream,
    round])`` — one independent stream per failure mode per round, each
    drawn as a full (N,) vector and indexed by the selected cohort, so
    outcomes do not depend on cohort composition or selection order.

    Args:
        num_clients: N, the population ceiling (client ids 0..N-1).
        spec:        :class:`TraceSpec` failure-mode knobs.
        seed:        trace seed; ``(seed, spec)`` fixes every replay.
    """

    def __init__(self, num_clients: int, spec: TraceSpec = TraceSpec(), *,
                 seed: int = 0):
        if num_clients <= 0:
            raise ValueError("ClientTrace needs num_clients >= 1")
        if spec.availability not in ("none", "diurnal"):
            raise ValueError(f"unknown availability model "
                             f"{spec.availability!r}")
        if not spec.tiers or any(t <= 0 for t in spec.tiers):
            raise ValueError("TraceSpec.tiers must be positive stretches")
        self.num_clients = num_clients
        self.spec = spec
        self.seed = seed
        rng = self._rng("static", 0)
        n = num_clients
        if spec.phase_assign is not None:
            self.phase = self._per_client("phase_assign",
                                          spec.phase_assign, np.float64)
        else:
            self.phase = rng.random(n)
        if spec.tier_assign is not None:
            tier = self._per_client("tier_assign", spec.tier_assign, np.int64)
            if len(spec.tiers) and (tier.min() < 0
                                    or tier.max() >= len(spec.tiers)):
                raise ValueError(f"tier_assign indexes outside "
                                 f"{len(spec.tiers)} tiers")
            self.tier = tier
        else:
            self.tier = rng.integers(0, len(spec.tiers), n)
        if spec.hazard_assign is not None:
            self.hazard_mult = self._per_client("hazard_assign",
                                                spec.hazard_assign,
                                                np.float64)
        else:
            self.hazard_mult = np.ones(n)
        self.stretch = np.asarray(spec.tiers, np.float64)[self.tier]
        # membership history: _membership[r] is the active mask BEFORE
        # round r; computed lazily round by round so it is a pure
        # function of (seed, spec, r)
        self._membership: List[np.ndarray] = [np.ones(n, bool)]

    def _per_client(self, name: str, values, dtype) -> np.ndarray:
        arr = np.asarray(values, dtype)
        if arr.shape != (self.num_clients,):
            raise ValueError(f"TraceSpec.{name} must have one entry per "
                             f"client ({self.num_clients}), got shape "
                             f"{arr.shape}")
        return arr

    def _rng(self, stream: str, round_idx: int) -> np.random.Generator:
        """Independent deterministic generator per (stream, round)."""
        return np.random.default_rng(np.random.SeedSequence(
            [self.seed, _STREAMS[stream], round_idx]))

    # -- availability ------------------------------------------------------
    def availability(self, t_s: float) -> np.ndarray:
        """(N,) per-client availability probability at simulated time t.

        ``"none"`` is all-ones; ``"diurnal"`` is a floor+amplitude
        sinusoid over ``day_period_s`` with each client's own phase.
        Always clipped to [0, 1] regardless of the knob values.
        """
        s = self.spec
        if s.availability == "none":
            return np.ones(self.num_clients)
        wave = 0.5 * (1.0 + np.sin(
            2.0 * np.pi * (t_s / max(s.day_period_s, 1e-9) + self.phase)))
        return np.clip(s.avail_floor + s.avail_amplitude * wave, 0.0, 1.0)

    # -- churn -------------------------------------------------------------
    def membership(self, round_idx: int) -> np.ndarray:
        """(N,) bool: who is in the population going into ``round_idx``.

        Round 0 starts with everyone active; each subsequent round every
        active client leaves w.p. ``p_leave`` and every departed client
        rejoins w.p. ``p_join`` (independent deterministic draws).
        """
        if round_idx < 0:
            raise ValueError("round_idx must be >= 0")
        s = self.spec
        while len(self._membership) <= round_idx:
            r = len(self._membership)
            prev = self._membership[-1]
            if s.p_leave <= 0.0 and s.p_join <= 0.0:
                self._membership.append(prev)
                continue
            u = self._rng("churn", r).random(self.num_clients)
            nxt = np.where(prev, u >= s.p_leave, u < s.p_join)
            self._membership.append(nxt)
        return self._membership[round_idx]

    def churn_step(self, round_idx: int) -> Tuple[np.ndarray, np.ndarray]:
        """(joined_ids, left_ids) between rounds ``r-1`` and ``r``.

        This is the delta stream the serving path feeds straight into
        ``CohortServer.update_embeddings`` (joins carry fresh embedding
        rows, leaves tombstone theirs) — O(delta) by construction.
        Round 0 reports no churn.
        """
        if round_idx == 0:
            empty = np.empty(0, np.int64)
            return empty, empty
        prev = self.membership(round_idx - 1)
        cur = self.membership(round_idx)
        return (np.flatnonzero(~prev & cur).astype(np.int64),
                np.flatnonzero(prev & ~cur).astype(np.int64))

    # -- latency -----------------------------------------------------------
    def latencies(self, round_idx: int) -> np.ndarray:
        """(N,) simulated round-trip latency had each client been selected.

        ``base_latency_s × tier stretch × lognormal(σ=latency_jitter)``
        — the jitter draw is independent of the tier, so stretching a
        tier stretches every latency monotonically (the property suite
        pins this).
        """
        s = self.spec
        jitter = np.exp(s.latency_jitter
                        * self._rng("latency", round_idx)
                        .standard_normal(self.num_clients))
        return s.base_latency_s * self.stretch * jitter

    # -- the round ---------------------------------------------------------
    def simulate_round(self, round_idx: int, now_s: float,
                       selected: Sequence[int],
                       spec: Optional[RoundSpec] = None) -> RoundOutcome:
        """Run one round's failure modes over the selected cohort.

        Per selected client, in order: (1) departed or unavailable →
        dropped immediately (connection refused, costs no wall time);
        (2) latency past the deadline → dropped, server waits the full
        deadline; (3) mid-round dropout with probability
        ``1 − exp(−hazard × exposure)`` → dropped, disconnect partway
        through; (4) otherwise completed.  The round's simulated wall
        time is the latest event the server observes: completions at
        their latency, dropouts at their disconnect, deadline-misses at
        the deadline.
        """
        rs = spec or RoundSpec()
        sel = np.asarray(selected, np.int64)
        k = len(sel)
        if k == 0:
            empty = np.empty(0, np.int64)
            return RoundOutcome(round_idx, sel, empty, empty, empty,
                                np.empty(0), 0.0, rs.deadline_s,
                                {"unavailable": 0, "deadline": 0,
                                 "dropout": 0})
        s = self.spec
        member = self.membership(round_idx)[sel]
        avail_p = self.availability(now_s)[sel]
        u_avail = self._rng("availability", round_idx).random(
            self.num_clients)[sel]
        responds = member & (u_avail < avail_p)

        lat = self.latencies(round_idx)[sel]
        missed = (np.zeros(k, bool) if rs.deadline_s is None
                  else lat > rs.deadline_s)

        exposure = (lat if rs.deadline_s is None
                    else np.minimum(lat, rs.deadline_s))
        hazard = s.dropout_hazard * self.hazard_mult[sel]
        p_drop = 1.0 - np.exp(-np.maximum(hazard, 0.0) * exposure)
        u_drop = self._rng("dropout", round_idx).random(self.num_clients)[sel]
        drop_frac = self._rng("drop_frac", round_idx).random(
            self.num_clients)[sel]
        dropped_mid = responds & ~missed & (u_drop < p_drop)

        completed_mask = responds & ~missed & ~dropped_mid
        # what the server observes, per selected client: nothing for a
        # refused connection, the disconnect for a dropout, the full
        # deadline for a miss, the latency for a completion
        event = np.zeros(k)
        event[completed_mask] = lat[completed_mask]
        event[dropped_mid] = (lat * drop_frac)[dropped_mid]
        if rs.deadline_s is not None:
            event[responds & missed] = rs.deadline_s
        elapsed = float(event.max()) if k else 0.0

        median = float(np.median(lat[responds])) if responds.any() else 0.0
        stragglers = responds & (lat > rs.straggler_mult * max(median, 1e-12))
        reasons = {
            "unavailable": int(np.count_nonzero(~responds)),
            "deadline": int(np.count_nonzero(responds & missed)),
            "dropout": int(np.count_nonzero(dropped_mid)),
        }
        return RoundOutcome(
            round_idx, sel,
            completed=sel[completed_mask],
            dropped=sel[~completed_mask],
            straggler_ids=sel[stragglers],
            latencies_s=lat, elapsed_s=elapsed,
            deadline_s=rs.deadline_s, reasons=reasons)


# -- aggregation + reward helpers the round driver wires in ----------------

def filter_survivors(stacked_params, weights: np.ndarray,
                     survivor_mask: np.ndarray):
    """Drop non-surviving cohort members before FedAvg.

    Slices the leading cohort axis of the stacked client params down to
    the survivors; ``fedavg_aggregate`` renormalizes the surviving
    weights internally, so a dropped client contributes exactly nothing
    (even NaN partial work cannot poison the mean — the chaos suite
    asserts this).  Raises if nobody survived: the caller must skip
    aggregation entirely for an all-dropped round.
    """
    import jax

    mask = np.asarray(survivor_mask, bool)
    if not mask.any():
        raise ValueError("filter_survivors: no survivors to aggregate")
    if mask.all():
        return stacked_params, weights
    idx = np.flatnonzero(mask)
    return (jax.tree.map(lambda x: x[idx], stacked_params),
            np.asarray(weights)[idx])


def blended_reward(accuracy: float, target: float, attainment: float, *,
                   blend: float = 0.5, xi: float = 64.0) -> float:
    """Deadline-aware FAVOR shaping: accuracy blended with attainment.

    ``(1−b)·(Ξ^(acc−target) − 1) + b·(attainment − 1)`` — the
    attainment term is 0 when every selected client beat the deadline
    and −1 when none did, so a policy that wastes cohort slots on
    slow/flaky clusters pays for it every round even before the
    accuracy signal moves.  ``blend=0`` is exactly the paper's reward.
    """
    if not 0.0 <= blend <= 1.0:
        raise ValueError(f"blend={blend} must be in [0, 1]")
    base = float(xi ** (accuracy - target) - 1.0)
    return (1.0 - blend) * base + blend * (float(attainment) - 1.0)
