from repro.fed.datasets import make_dataset, DATASETS
from repro.fed.partition import partition_non_iid, sigma_to_alpha
from repro.fed.client import local_train
from repro.fed.server import fedavg_aggregate, weight_delta_embedding
from repro.fed.realism import (ClientTrace, RoundOutcome, RoundSpec,
                               SimClock, TraceSpec, blended_reward,
                               filter_survivors)
from repro.fed.rounds import FederatedRunner, RoundResult, RunnerConfig
from repro.fed.metrics import (classification_metrics, cluster_policy_state,
                               serving_state_dim)

__all__ = ["make_dataset", "DATASETS", "partition_non_iid", "sigma_to_alpha",
           "local_train", "fedavg_aggregate", "weight_delta_embedding",
           "FederatedRunner", "RoundResult", "RunnerConfig",
           "ClientTrace", "RoundOutcome", "RoundSpec", "SimClock",
           "TraceSpec", "blended_reward", "filter_survivors",
           "classification_metrics", "cluster_policy_state",
           "serving_state_dim"]
