"""Non-IID partitioning of a dataset across federated clients.

The paper parameterizes heterogeneity with σ ∈ {0, 0.5, 0.8, 1} but never
defines it; we map it onto the standard Dirichlet(α) label-skew knob
(Hsu et al. 2019), preserving the paper's ordering "σ=1 ⇒ hardest
non-IID" (DESIGN.md §8.2):

    σ:    0.0    0.5    0.8    1.0
    α:  1000.0   1.0    0.3    0.1
"""

from __future__ import annotations

import numpy as np

_SIGMA_TABLE = {0.0: 1000.0, 0.5: 1.0, 0.8: 0.3, 1.0: 0.1}


def sigma_to_alpha(sigma: float) -> float:
    if sigma in _SIGMA_TABLE:
        return _SIGMA_TABLE[sigma]
    # smooth interpolation for off-grid sigmas
    return float(np.interp(sigma, [0.0, 0.5, 0.8, 1.0],
                           [1000.0, 1.0, 0.3, 0.1]))


def partition_non_iid(y: np.ndarray, num_clients: int, sigma: float,
                      *, seed: int = 0, min_per_client: int = 8):
    """Dirichlet label-skew split.  Returns list of index arrays."""
    alpha = sigma_to_alpha(sigma)
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    idx_by_class = [np.flatnonzero(y == c) for c in classes]
    for idx in idx_by_class:
        rng.shuffle(idx)

    client_indices = [[] for _ in range(num_clients)]
    for idx in idx_by_class:
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idx, cuts)):
            client_indices[cid].append(part)
    out = [np.concatenate(parts) for parts in client_indices]

    # guarantee a minimum shard size so local SGD is well-defined
    pool = np.concatenate(out)
    for cid in range(num_clients):
        if len(out[cid]) < min_per_client:
            extra = rng.choice(pool, size=min_per_client - len(out[cid]),
                               replace=False)
            out[cid] = np.concatenate([out[cid], extra])
        rng.shuffle(out[cid])
    return out


def label_histogram(y: np.ndarray, indices, num_classes: int) -> np.ndarray:
    """Per-client class histograms — used by tests & the K-Center policy."""
    return np.stack([np.bincount(y[idx], minlength=num_classes)
                     for idx in indices]).astype(np.float32)
