"""Client-side local training, vmapped across the selected cohort.

All selected clients train **in parallel** as one jitted computation: the
global model is broadcast, per-client data is stacked along a leading
cohort axis, and ``jax.vmap`` maps the local-SGD scan over it.  On a real
mesh the cohort axis shards over ``data`` (this is the datacenter-FL
simulation pattern — DESIGN.md §3); on this container it runs on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.cnn import cnn_loss


def sgd_tree(params, grads, lr):
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)


def local_train(loss_fn, params, xs, ys, rng, lr):
    """Local SGD.  xs: (steps, bs, ...), ys: (steps, bs)."""

    def step(carry, xy):
        params, rng = carry
        rng, sub = jax.random.split(rng)
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, {"x": xy[0], "y": xy[1]}, sub)
        return (sgd_tree(params, grads, lr), rng), loss

    (params, _), losses = jax.lax.scan(step, (params, rng), (xs, ys))
    return params, jnp.mean(losses)


@functools.partial(jax.jit, static_argnames=("lr",))
def local_train_cohort(params, xs, ys, rngs, *, lr: float):
    """vmapped local training.

    params: global model pytree (broadcast).
    xs: (K, steps, bs, H, W, C); ys: (K, steps, bs); rngs: (K, 2) keys.
    Returns (stacked client params with leading K axis, (K,) mean losses).
    """
    def one(x, y, r):
        return local_train(cnn_loss, params, x, y, r, lr)

    return jax.vmap(one)(xs, ys, rngs)


@jax.jit
def evaluate(params, x, y):
    """Full-batch eval: returns (accuracy, mean loss, logits)."""
    loss, logits = cnn_loss(params, {"x": x, "y": y})
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return acc, loss, logits
