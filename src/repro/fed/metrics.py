"""Evaluation criteria for the paper's Table 3, plus serving-state stats.

Balanced accuracy, accuracy, macro recall, Cohen's kappa, macro one-vs-rest
AUC (rank-based, no sklearn), plus the "feature rate" (the paper's term;
we read it as macro precision, the closest standard quantity).

Also home to :func:`cluster_policy_state` — the per-cluster
participation/accuracy statistics the serving path feeds the DQN policy
(``repro.policy.ClusterPolicy``) as its state vector.
"""

from __future__ import annotations

import numpy as np


def cluster_policy_state(assign: np.ndarray, k: int,
                         participation: np.ndarray,
                         reward_ema: np.ndarray,
                         prev_accuracy: float) -> np.ndarray:
    """Serving-side DQN state: per-cluster stats + last global accuracy.

    Args:
        assign:        (n,) cluster ids in [0, k) from Algorithm I.
        k:             number of clusters (the DQN action count).
        participation: (k,) cumulative count of cohort slots served from
                       each cluster so far.
        reward_ema:    (k,) exponential moving average of the round
                       reward credited to draws from each cluster.
        prev_accuracy: global-model accuracy after the last round.

    Returns:
        (3k + 1,) float32 vector ``[population_frac ‖ participation_frac
        ‖ reward_ema ‖ prev_accuracy]`` — population fraction is each
        cluster's share of clients, participation fraction its share of
        all slots served (uniform 1/k before any draw, so round 0 is not
        a degenerate all-zeros state).
    """
    n = max(len(assign), 1)
    pop = np.bincount(np.asarray(assign), minlength=k)[:k] / n
    participation = np.asarray(participation, np.float64)[:k]
    total = participation.sum()
    part = (participation / total) if total > 0 else np.full(k, 1.0 / k)
    return np.concatenate(
        [pop, part, np.asarray(reward_ema, np.float64)[:k],
         [prev_accuracy]]).astype(np.float32)


def confusion(y_true: np.ndarray, y_pred: np.ndarray, k: int) -> np.ndarray:
    cm = np.zeros((k, k), np.int64)
    np.add.at(cm, (y_true, y_pred), 1)
    return cm


def classification_metrics(y_true: np.ndarray, logits: np.ndarray) -> dict:
    k = logits.shape[-1]
    y_pred = np.argmax(logits, axis=-1)
    cm = confusion(y_true, y_pred, k)
    total = cm.sum()
    acc = np.trace(cm) / max(total, 1)

    per_class_recall = np.divide(np.diag(cm), cm.sum(axis=1),
                                 out=np.zeros(k), where=cm.sum(axis=1) > 0)
    per_class_prec = np.divide(np.diag(cm), cm.sum(axis=0),
                               out=np.zeros(k), where=cm.sum(axis=0) > 0)
    balanced_acc = per_class_recall.mean()
    recall = per_class_recall.mean()
    precision = per_class_prec.mean()

    # Cohen's kappa
    pe = float((cm.sum(axis=0) * cm.sum(axis=1)).sum()) / max(total ** 2, 1)
    kappa = (acc - pe) / max(1 - pe, 1e-12)

    # macro one-vs-rest AUC via the rank statistic
    aucs = []
    for c in range(k):
        pos = logits[y_true == c, c]
        neg = logits[y_true != c, c]
        if len(pos) == 0 or len(neg) == 0:
            continue
        ranks = np.argsort(np.argsort(np.concatenate([pos, neg])))
        auc = (ranks[: len(pos)].sum() - len(pos) * (len(pos) - 1) / 2) \
            / (len(pos) * len(neg))
        aucs.append(auc)
    auc = float(np.mean(aucs)) if aucs else 0.5

    return {"balanced_accuracy": float(balanced_acc), "accuracy": float(acc),
            "recall": float(recall), "kappa": float(kappa),
            "precision": float(precision), "auc": auc}
