"""Evaluation criteria for the paper's Table 3.

Balanced accuracy, accuracy, macro recall, Cohen's kappa, macro one-vs-rest
AUC (rank-based, no sklearn), plus the "feature rate" (the paper's term;
we read it as macro precision, the closest standard quantity).
"""

from __future__ import annotations

import numpy as np


def confusion(y_true: np.ndarray, y_pred: np.ndarray, k: int) -> np.ndarray:
    cm = np.zeros((k, k), np.int64)
    np.add.at(cm, (y_true, y_pred), 1)
    return cm


def classification_metrics(y_true: np.ndarray, logits: np.ndarray) -> dict:
    k = logits.shape[-1]
    y_pred = np.argmax(logits, axis=-1)
    cm = confusion(y_true, y_pred, k)
    total = cm.sum()
    acc = np.trace(cm) / max(total, 1)

    per_class_recall = np.divide(np.diag(cm), cm.sum(axis=1),
                                 out=np.zeros(k), where=cm.sum(axis=1) > 0)
    per_class_prec = np.divide(np.diag(cm), cm.sum(axis=0),
                               out=np.zeros(k), where=cm.sum(axis=0) > 0)
    balanced_acc = per_class_recall.mean()
    recall = per_class_recall.mean()
    precision = per_class_prec.mean()

    # Cohen's kappa
    pe = float((cm.sum(axis=0) * cm.sum(axis=1)).sum()) / max(total ** 2, 1)
    kappa = (acc - pe) / max(1 - pe, 1e-12)

    # macro one-vs-rest AUC via the rank statistic
    aucs = []
    for c in range(k):
        pos = logits[y_true == c, c]
        neg = logits[y_true != c, c]
        if len(pos) == 0 or len(neg) == 0:
            continue
        ranks = np.argsort(np.argsort(np.concatenate([pos, neg])))
        auc = (ranks[: len(pos)].sum() - len(pos) * (len(pos) - 1) / 2) \
            / (len(pos) * len(neg))
        aucs.append(auc)
    auc = float(np.mean(aucs)) if aucs else 0.5

    return {"balanced_accuracy": float(balanced_acc), "accuracy": float(acc),
            "recall": float(recall), "kappa": float(kappa),
            "precision": float(precision), "auc": auc}
