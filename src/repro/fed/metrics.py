"""Evaluation criteria for the paper's Table 3, plus serving-state stats.

Balanced accuracy, accuracy, macro recall, Cohen's kappa, macro one-vs-rest
AUC (rank-based, no sklearn), plus the "feature rate" (the paper's term;
we read it as macro precision, the closest standard quantity).

Also home to :func:`cluster_policy_state` — the per-cluster
participation/accuracy statistics the serving path feeds the DQN policy
(``repro.policy.ClusterPolicy``) as its state vector.  Two feature sets
are supported (the ``features`` knob, mirrored by
``CohortServer(state_features=...)``):

* ``"basic"`` — the original ``3k + 1`` layout: population fraction ‖
  participation fraction ‖ reward EMA ‖ previous accuracy.  Kept for
  replay-buffer back-compat: checkpointed/replayed transitions recorded
  against the narrow state keep their shape.
* ``"rich"``  — ``5k + 1``: the basic features plus per-cluster
  embedding **dispersion** (how spread out each cluster is around its
  centroid, relative to the global spread) and **staleness** (how many
  selects since each cluster last contributed a client to a served
  cohort).  This is the serving analogue of the simulation state's
  cluster centroids — the served DQN sees cohesion and recency, not
  just participation bookkeeping.
* ``"system"`` — ``7k + 1``: the rich features plus per-cluster
  **availability** (EMA of the completed/dropped outcome of each
  cluster's served clients, from ``repro.fed.realism`` round outcomes)
  and **mean latency** (EMA of simulated round-trip seconds, squashed
  to [0, 1)).  This is what lets the served DQN learn to route cohort
  slots away from slow or flaky clusters, not just skewed ones.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

#: recognised feature sets for :func:`cluster_policy_state`.
STATE_FEATURES = ("basic", "rich", "system")

#: per-cluster feature count of each layout (+1 for prev_accuracy).
_FEATURES_PER_CLUSTER = {"basic": 3, "rich": 5, "system": 7}


def serving_state_dim(k: int, features: str = "rich") -> int:
    """State-vector length of :func:`cluster_policy_state`.

    ``3k + 1`` for ``"basic"`` (population / participation / reward EMA
    + previous accuracy), ``5k + 1`` for ``"rich"`` (+ dispersion and
    staleness per cluster), ``7k + 1`` for ``"system"`` (+ availability
    and mean-latency per cluster).
    """
    if features not in STATE_FEATURES:
        raise ValueError(f"unknown state features {features!r}; "
                         f"expected one of {STATE_FEATURES}")
    return _FEATURES_PER_CLUSTER[features] * k + 1


def _check_per_cluster(name: str, arr: np.ndarray, k: int) -> np.ndarray:
    """Validate a per-cluster stat vector: must cover all k clusters.

    A silently short array used to be truncated by ``[:k]`` into a
    wrong-length state that only failed much later, inside the DQN's
    first matmul.  Fail here instead, naming the offending argument.
    Longer arrays are still sliced to ``[:k]`` (callers that track
    stats for a historical k̂ > k keep working).
    """
    arr = np.asarray(arr, np.float64).reshape(-1)
    if len(arr) < k:
        raise ValueError(
            f"cluster_policy_state: {name} has length {len(arr)} but "
            f"k={k} clusters; per-cluster stats must cover every "
            f"cluster (pad missing clusters with zeros upstream)")
    return arr[:k]


def cluster_dispersion(embeds: np.ndarray, assign: np.ndarray,
                       k: int) -> np.ndarray:
    """Per-cluster embedding spread, scale-free and bounded to [0, 1).

    For each cluster: the mean squared distance of its members to the
    cluster centroid, divided by the global mean squared distance to the
    global centroid, squashed through ``x / (1 + x)``.  Empty clusters
    report 0.  A tight cluster sits near 0; one as diffuse as the whole
    table sits near 0.5; a cluster wider than the table tends to 1.
    """
    embeds = np.asarray(embeds, np.float64)
    assign = np.asarray(assign)
    global_var = float(
        np.mean(np.sum((embeds - embeds.mean(axis=0)) ** 2, axis=1)))
    out = np.zeros(k, np.float64)
    if global_var <= 0.0:
        return out
    for c in range(k):
        members = embeds[assign == c]
        if len(members) == 0:
            continue
        var = float(np.mean(
            np.sum((members - members.mean(axis=0)) ** 2, axis=1)))
        ratio = var / global_var
        out[c] = ratio / (1.0 + ratio)
    return out


def cluster_policy_state(assign: np.ndarray, k: int,
                         participation: np.ndarray,
                         reward_ema: np.ndarray,
                         prev_accuracy: float,
                         *,
                         embeds: Optional[np.ndarray] = None,
                         staleness: Optional[np.ndarray] = None,
                         availability: Optional[np.ndarray] = None,
                         latency_s: Optional[np.ndarray] = None,
                         features: str = "rich") -> np.ndarray:
    """Serving-side DQN state: per-cluster stats + last global accuracy.

    Args:
        assign:        (n,) cluster ids in [0, k) from Algorithm I.
        k:             number of clusters (the DQN action count).
        participation: (k,) cumulative count of cohort slots served from
                       each cluster so far.
        reward_ema:    (k,) exponential moving average of the round
                       reward credited to draws from each cluster.
        prev_accuracy: global-model accuracy after the last round.
        embeds:        (n, d) embedding table behind ``assign``; required
                       for ``features="rich"``/``"system"`` (dispersion).
        staleness:     (k,) count of selects since each cluster last
                       contributed a client to a served cohort; required
                       for ``features="rich"``/``"system"``.
        availability:  (k,) EMA in [0, 1] of each cluster's served
                       clients completing their round (vs dropping);
                       required for ``features="system"``.
        latency_s:     (k,) EMA of each cluster's simulated round-trip
                       seconds; required for ``features="system"``.
        features:      ``"basic"`` (3k + 1) | ``"rich"`` (5k + 1) |
                       ``"system"`` (7k + 1).

    Returns:
        float32 vector ``[population_frac ‖ participation_frac ‖
        reward_ema ( ‖ dispersion ‖ staleness_frac ( ‖ availability ‖
        latency_frac )) ‖ prev_accuracy]`` — population fraction is each
        cluster's share of clients, participation fraction its share of
        all slots served (uniform 1/k before any draw, so round 0 is
        not a degenerate all-zeros state), staleness and latency
        squashed to [0, 1) via ``x / (1 + x)``.
    """
    if features not in STATE_FEATURES:
        raise ValueError(f"unknown state features {features!r}; "
                         f"expected one of {STATE_FEATURES}")
    n = max(len(assign), 1)
    pop = np.bincount(np.asarray(assign), minlength=k)[:k] / n
    participation = _check_per_cluster("participation", participation, k)
    reward = _check_per_cluster("reward_ema", reward_ema, k)
    total = participation.sum()
    part = (participation / total) if total > 0 else np.full(k, 1.0 / k)
    parts = [pop, part, reward]
    if features in ("rich", "system"):
        if embeds is None:
            raise ValueError(
                f"cluster_policy_state: features={features!r} needs the "
                "embedding table (embeds=) for the dispersion features; "
                "pass features='basic' for the participation-only state")
        if staleness is None:
            raise ValueError(
                f"cluster_policy_state: features={features!r} needs the "
                "per-cluster staleness counts (staleness=)")
        stale = _check_per_cluster("staleness", staleness, k)
        parts.append(cluster_dispersion(embeds, assign, k))
        parts.append(stale / (1.0 + stale))
    if features == "system":
        if availability is None or latency_s is None:
            raise ValueError(
                "cluster_policy_state: features='system' needs the "
                "per-cluster availability (availability=) and mean "
                "latency (latency_s=) EMAs — the client-realism "
                "features from repro.fed.realism round outcomes")
        avail = np.clip(
            _check_per_cluster("availability", availability, k), 0.0, 1.0)
        lat = np.maximum(
            _check_per_cluster("latency_s", latency_s, k), 0.0)
        parts.append(avail)
        parts.append(lat / (1.0 + lat))
    parts.append([prev_accuracy])
    return np.concatenate(parts).astype(np.float32)


def confusion(y_true: np.ndarray, y_pred: np.ndarray, k: int) -> np.ndarray:
    cm = np.zeros((k, k), np.int64)
    np.add.at(cm, (y_true, y_pred), 1)
    return cm


def _midranks(scores: np.ndarray) -> np.ndarray:
    """1-based midranks: tied scores share the mean of their positions.

    The double-argsort trick assigns ties arbitrary *ordinal* ranks
    (whichever came first in memory wins), which biases the
    Mann–Whitney U statistic whenever logits tie — e.g. saturated
    softmax outputs or integer-ish scores.  Midranks are the standard
    tie correction: AUC under ties is then the probability of a correct
    ranking with ties counted as 1/2.
    """
    order = np.argsort(scores, kind="stable")
    sorted_scores = scores[order]
    n = len(scores)
    ranks = np.empty(n, np.float64)
    i = 0
    while i < n:
        j = i
        while j < n and sorted_scores[j] == sorted_scores[i]:
            j += 1
        ranks[i:j] = 0.5 * (i + j - 1) + 1.0     # mean of 1-based i+1..j
        i = j
    out = np.empty(n, np.float64)
    out[order] = ranks
    return out


def classification_metrics(y_true: np.ndarray, logits: np.ndarray) -> dict:
    k = logits.shape[-1]
    y_pred = np.argmax(logits, axis=-1)
    cm = confusion(y_true, y_pred, k)
    total = cm.sum()
    acc = np.trace(cm) / max(total, 1)

    per_class_recall = np.divide(np.diag(cm), cm.sum(axis=1),
                                 out=np.zeros(k), where=cm.sum(axis=1) > 0)
    per_class_prec = np.divide(np.diag(cm), cm.sum(axis=0),
                               out=np.zeros(k), where=cm.sum(axis=0) > 0)
    balanced_acc = per_class_recall.mean()
    recall = per_class_recall.mean()
    precision = per_class_prec.mean()

    # Cohen's kappa
    pe = float((cm.sum(axis=0) * cm.sum(axis=1)).sum()) / max(total ** 2, 1)
    kappa = (acc - pe) / max(1 - pe, 1e-12)

    # macro one-vs-rest AUC via the Mann–Whitney rank statistic, with
    # midranks so tied logits contribute 1/2 instead of an order-of-
    # appearance bias
    aucs = []
    for c in range(k):
        pos = logits[y_true == c, c]
        neg = logits[y_true != c, c]
        if len(pos) == 0 or len(neg) == 0:
            continue
        ranks = _midranks(np.concatenate([pos, neg]))
        auc = (ranks[: len(pos)].sum() - len(pos) * (len(pos) + 1) / 2) \
            / (len(pos) * len(neg))
        aucs.append(auc)
    auc = float(np.mean(aucs)) if aucs else 0.5

    return {"balanced_accuracy": float(balanced_acc), "accuracy": float(acc),
            "recall": float(recall), "kappa": float(kappa),
            "precision": float(precision), "auc": auc}
