"""Server-side aggregation (FedAvg) and weight-delta embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def fedavg_aggregate(stacked_params, weights):
    """Weighted FedAvg.  stacked_params: pytree with leading cohort axis K;
    weights: (K,) — normalized inside (client shard sizes, per McMahan)."""
    w = weights / jnp.maximum(jnp.sum(weights), 1e-12)

    def mean(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        return jnp.sum(x * wb, axis=0)

    return jax.tree.map(mean, stacked_params)


@jax.jit
def params_delta(stacked_params, global_params):
    """Per-client parameter deltas vs the global model."""
    return jax.tree.map(lambda c, g: c - g[None], stacked_params,
                        jax.tree.map(jnp.asarray, global_params))


def weight_delta_embedding(embedder, stacked_params, global_params):
    """Embed each cohort member's weight delta -> (K, dim) numpy."""
    deltas = params_delta(stacked_params, global_params)
    return embedder.embed_many(deltas)
