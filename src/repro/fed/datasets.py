"""Synthetic stand-ins for MNIST / Fashion-MNIST / CIFAR-10.

The container is offline (DESIGN.md §8.1), so the paper's three datasets
are replaced by procedurally generated look-alikes with the same shapes
and cardinalities.  Each class is a smoothed random prototype image plus
per-sample noise and a random affine jitter; the class-separation scale is
tuned per dataset so the relative difficulty ordering matches the paper
(MNIST easiest, CIFAR-10 hardest).  All claims validated on these data are
*relative* (selection policy A vs B) — absolute accuracies are not
comparable to the paper's and are flagged as such in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    image_size: int
    channels: int
    num_classes: int
    train_size: int
    test_size: int
    separation: float      # prototype scale vs unit noise — task difficulty


DATASETS = {
    "mnist": DatasetSpec("mnist", 28, 1, 10, 60_000, 10_000, 2.5),
    "fashion_mnist": DatasetSpec("fashion_mnist", 28, 1, 10, 60_000, 10_000, 1.6),
    "cifar10": DatasetSpec("cifar10", 32, 3, 10, 50_000, 10_000, 0.9),
}


def _smooth(img: np.ndarray, passes: int = 2) -> np.ndarray:
    """Cheap box blur so prototypes have spatial structure like digits."""
    for _ in range(passes):
        img = (img
               + np.roll(img, 1, axis=0) + np.roll(img, -1, axis=0)
               + np.roll(img, 1, axis=1) + np.roll(img, -1, axis=1)) / 5.0
    return img


def make_dataset(name: str, *, seed: int = 0, train_size: int | None = None,
                 test_size: int | None = None):
    """Returns dict with x_train (N,H,W,C) float32, y_train (N,) int32,
    x_test, y_test."""
    spec = DATASETS[name]
    # crc32, not hash(): str hashes are randomized per process, and the
    # prototypes must replay bit-identically across processes (the
    # realism CI gate re-runs the exact grid recorded in BENCH_fed.json)
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % (2 ** 16))
    n_tr = train_size or spec.train_size
    n_te = test_size or spec.test_size
    H = spec.image_size

    protos = rng.normal(size=(spec.num_classes, H, H, spec.channels))
    protos = np.stack([_smooth(p) for p in protos]) * spec.separation

    def gen(n, seed_off):
        r = np.random.default_rng(seed + seed_off)
        y = r.integers(0, spec.num_classes, size=n).astype(np.int32)
        x = protos[y].astype(np.float32)
        # random per-sample translation jitter (±2 px) + pixel noise
        shifts = r.integers(-2, 3, size=(n, 2))
        for axis in (1, 2):
            # vectorized roll by unique shift values
            for s in range(-2, 3):
                m = shifts[:, axis - 1] == s
                if s and m.any():
                    x[m] = np.roll(x[m], s, axis=axis)
        x = x + r.normal(size=x.shape).astype(np.float32)
        return x, y

    x_tr, y_tr = gen(n_tr, 1)
    x_te, y_te = gen(n_te, 2)
    return {"x_train": x_tr, "y_train": y_tr, "x_test": x_te, "y_test": y_te,
            "spec": spec}
