"""The federated communication-round driver (Algorithm II outer loop).

One ``FederatedRunner`` = one experiment: a dataset partitioned non-IID
across N simulated clients, a selection policy, and the FedAvg server.
Each round: select cohort -> parallel local SGD (vmapped) -> aggregate ->
evaluate -> reward the policy.  Rounds-to-target-accuracy is the paper's
headline metric (Table 2).
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import numpy as np

from repro.core.embedding import WeightEmbedder
from repro.core.selection import (Feedback, RoundState, favor_reward,
                                  make_policy)
from repro.fed.client import evaluate, local_train_cohort
from repro.fed.datasets import make_dataset
from repro.fed.metrics import classification_metrics
from repro.fed.partition import partition_non_iid
from repro.fed.server import fedavg_aggregate, weight_delta_embedding
from repro.models.cnn import cnn_init


@dataclasses.dataclass
class RoundResult:
    round_idx: int
    accuracy: float
    loss: float
    reward: float
    selected: np.ndarray
    seconds: float
    # per-phase wall times (monotonic perf_counter): select / train /
    # aggregate / evaluate / update — so cohort-selection cost is
    # attributable separately from local SGD when profiling a run.
    timings: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class RunnerConfig:
    dataset: str = "mnist"
    num_clients: int = 100
    clients_per_round: int = 10
    sigma: float = 0.5
    local_steps: int = 10
    batch_size: int = 16
    lr: float = 0.05
    embed_dim: int = 8
    num_clusters: int = 8
    target_accuracy: float = 0.85
    eval_size: int = 1024
    train_size: Optional[int] = 8192       # subsample for CPU tractability
    seed: int = 0
    policy: str = "fedavg"
    use_pallas: bool = False
    # Algorithm I scale regime, resolved by the cohort engine:
    # "dense" | "nystrom" | "sharded" | "auto"
    approx_method: str = "dense"
    num_landmarks: Optional[int] = None    # Nyström landmark count (m ≪ N)
    landmarks: str = "uniform"             # "uniform" | "leverage" | "kmeans++"
    warm_start: bool = True                # drift-gated re-clustering
    # ε-greedy exploration schedule of the learning policies (favor /
    # dqre_sc): linear decay eps_start -> eps_end over eps_decay_steps
    # rounds.  Explicit dqn_overrides in policy_kwargs win over these.
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 200
    policy_kwargs: Optional[dict] = None


class FederatedRunner:
    def __init__(self, cfg: RunnerConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.rng = rng
        data = make_dataset(cfg.dataset, seed=cfg.seed,
                            train_size=cfg.train_size,
                            test_size=cfg.eval_size)
        self.spec = data["spec"]
        self.x_train, self.y_train = data["x_train"], data["y_train"]
        self.x_test, self.y_test = data["x_test"], data["y_test"]
        self.shards = partition_non_iid(self.y_train, cfg.num_clients,
                                        cfg.sigma, seed=cfg.seed)
        self.shard_sizes = np.array([len(s) for s in self.shards], np.float32)

        key = jax.random.PRNGKey(cfg.seed)
        self.global_params = cnn_init(
            key, in_channels=self.spec.channels,
            num_classes=self.spec.num_classes,
            image_size=self.spec.image_size)
        self.embedder = WeightEmbedder(self.global_params,
                                       dim=cfg.embed_dim, seed=cfg.seed)
        self.client_embeds = np.zeros((cfg.num_clients, cfg.embed_dim),
                                      np.float32)
        kw = dict(cfg.policy_kwargs or {})
        if cfg.policy == "dqre_sc":
            kw.setdefault("num_clusters", cfg.num_clusters)
            kw.setdefault("use_pallas", cfg.use_pallas)
            kw.setdefault("approx_method", cfg.approx_method)
            kw.setdefault("num_landmarks", cfg.num_landmarks)
            kw.setdefault("landmarks", cfg.landmarks)
            kw.setdefault("warm_start", cfg.warm_start)
        if cfg.policy in ("dqre_sc", "favor"):
            sched = dict(eps_start=cfg.eps_start, eps_end=cfg.eps_end,
                         eps_decay_steps=cfg.eps_decay_steps)
            sched.update(kw.get("dqn_overrides") or {})
            kw["dqn_overrides"] = sched
        self.policy = make_policy(cfg.policy, cfg.num_clients,
                                  cfg.clients_per_round, cfg.embed_dim,
                                  seed=cfg.seed, **kw)
        self.prev_acc = 0.0
        self.round_idx = 0
        self.history: List[RoundResult] = []
        self._warmed_up = False

    # ------------------------------------------------------------------
    def _client_batches(self, client_ids):
        c = self.cfg
        xs, ys = [], []
        for cid in client_ids:
            idx = self.rng.choice(self.shards[cid],
                                  size=c.local_steps * c.batch_size,
                                  replace=True)
            xs.append(self.x_train[idx].reshape(
                c.local_steps, c.batch_size, *self.x_train.shape[1:]))
            ys.append(self.y_train[idx].reshape(c.local_steps, c.batch_size))
        return np.stack(xs), np.stack(ys)

    def _train_cohort(self, client_ids):
        xs, ys = self._client_batches(client_ids)
        rngs = jax.random.split(jax.random.PRNGKey(
            self.cfg.seed * 100_003 + self.round_idx), len(client_ids))
        return local_train_cohort(self.global_params, xs, ys, rngs,
                                  lr=self.cfg.lr)

    def warmup(self):
        """One local pass on EVERY client to initialize the weight-state
        embeddings (FAVOR's initialization round; paper §3.4)."""
        ids = np.arange(self.cfg.num_clients)
        for lo in range(0, len(ids), 32):          # chunk to bound memory
            chunk = ids[lo: lo + 32]
            stacked, _ = self._train_cohort(chunk)
            self.client_embeds[chunk] = weight_delta_embedding(
                self.embedder, stacked, self.global_params)
        self._warmed_up = True

    def _round_state(self) -> RoundState:
        return RoundState(self.round_idx, self.client_embeds.copy(),
                          self.embedder(self.global_params),
                          self.prev_acc)

    # ------------------------------------------------------------------
    def run_round(self) -> RoundResult:
        if not self._warmed_up:
            self.warmup()
        c = self.cfg
        # perf_counter, not time.time(): monotonic, unaffected by NTP
        # slews, and the basis of the per-phase attribution below.
        t0 = time.perf_counter()
        state = self._round_state()
        selected = np.asarray(self.policy.select(state))
        t_select = time.perf_counter()

        stacked, losses = self._train_cohort(selected)
        self.client_embeds[selected] = weight_delta_embedding(
            self.embedder, stacked, self.global_params)
        t_train = time.perf_counter()
        weights = self.shard_sizes[selected]
        self.global_params = fedavg_aggregate(stacked, weights)
        t_aggregate = time.perf_counter()

        acc, loss, _ = evaluate(self.global_params, self.x_test, self.y_test)
        # round boundary: accuracy immediately drives the host-side
        # reward shaping and policy update, so this sync is inherent
        # repro-lint: ignore[jax-blocking-sync]
        acc = float(acc)
        t_evaluate = time.perf_counter()
        reward = favor_reward(acc, c.target_accuracy)
        next_state = self._round_state()
        self.policy.update(state, next_state,
                           Feedback(acc, reward, selected))
        self.prev_acc = acc
        t_update = time.perf_counter()
        # repro-lint: ignore[jax-blocking-sync] — same round boundary
        res = RoundResult(self.round_idx, acc, float(loss), reward, selected,
                          t_update - t0,
                          timings={"select": t_select - t0,
                                   "train": t_train - t_select,
                                   "aggregate": t_aggregate - t_train,
                                   "evaluate": t_evaluate - t_aggregate,
                                   "update": t_update - t_evaluate})
        self.history.append(res)
        self.round_idx += 1
        return res

    def run(self, num_rounds: int, stop_at_target: bool = False):
        for _ in range(num_rounds):
            res = self.run_round()
            if stop_at_target and res.accuracy >= self.cfg.target_accuracy:
                break
        return self.history

    # ------------------------------------------------------------------
    def rounds_to_accuracy(self, target: Optional[float] = None):
        target = target if target is not None else self.cfg.target_accuracy
        for res in self.history:
            if res.accuracy >= target:
                return res.round_idx + 1
        return None

    def final_metrics(self) -> dict:
        _, _, logits = evaluate(self.global_params, self.x_test, self.y_test)
        return classification_metrics(self.y_test, np.asarray(logits))
