"""The federated communication-round driver (Algorithm II outer loop).

One ``FederatedRunner`` = one experiment: a dataset partitioned non-IID
across N simulated clients, a selection policy, and the FedAvg server.
Each round: select cohort -> parallel local SGD (vmapped) -> aggregate ->
evaluate -> reward the policy.  Rounds-to-target-accuracy is the paper's
headline metric (Table 2).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.core.embedding import WeightEmbedder
from repro.core.selection import (Feedback, RoundState, favor_reward,
                                  make_policy)
from repro.fed.client import evaluate, local_train_cohort
from repro.fed.datasets import make_dataset
from repro.fed.metrics import classification_metrics
from repro.fed.partition import partition_non_iid
from repro.fed.realism import (ClientTrace, RoundOutcome, RoundSpec,
                               SimClock, TraceSpec, blended_reward,
                               filter_survivors)
from repro.fed.server import fedavg_aggregate, weight_delta_embedding
from repro.models.cnn import cnn_init


@dataclasses.dataclass
class RoundResult:
    round_idx: int
    accuracy: float
    loss: float
    reward: float
    selected: np.ndarray
    seconds: float
    # per-phase wall times through the runner's injectable clock
    # (monotonic perf_counter by default; the realism layer's SimClock
    # when a trace is attached, so benchmarks and replay tests agree):
    # select / train / aggregate / evaluate / update — cohort-selection
    # cost stays attributable separately from local SGD when profiling.
    timings: dict = dataclasses.field(default_factory=dict)
    # client-realism accounting (zeros / None without a trace): how many
    # of the selected cohort made aggregation, how many were dropped
    # (unavailable / past-deadline / mid-round dropout), how many were
    # stragglers, the round's simulated wall time, and the full outcome.
    num_completed: int = 0
    num_dropped: int = 0
    num_stragglers: int = 0
    sim_seconds: float = 0.0
    outcome: Optional[RoundOutcome] = None


@dataclasses.dataclass
class RunnerConfig:
    dataset: str = "mnist"
    num_clients: int = 100
    clients_per_round: int = 10
    sigma: float = 0.5
    local_steps: int = 10
    batch_size: int = 16
    lr: float = 0.05
    embed_dim: int = 8
    num_clusters: int = 8
    target_accuracy: float = 0.85
    eval_size: int = 1024
    train_size: Optional[int] = 8192       # subsample for CPU tractability
    seed: int = 0
    policy: str = "fedavg"
    use_pallas: bool = False
    # Algorithm I scale regime, resolved by the cohort engine:
    # "dense" | "nystrom" | "sharded" | "auto"
    approx_method: str = "dense"
    num_landmarks: Optional[int] = None    # Nyström landmark count (m ≪ N)
    landmarks: str = "uniform"             # "uniform" | "leverage" | "kmeans++"
    warm_start: bool = True                # drift-gated re-clustering
    # ε-greedy exploration schedule of the learning policies (favor /
    # dqre_sc): linear decay eps_start -> eps_end over eps_decay_steps
    # rounds.  Explicit dqn_overrides in policy_kwargs win over these.
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 200
    policy_kwargs: Optional[dict] = None
    # client realism (fed/realism.py): a TraceSpec switches the runner
    # onto the fault-injection layer (diurnal availability, straggler
    # tiers, mid-round dropout, churn) driven by an owned SimClock;
    # round_spec adds the wall-clock deadline + deadline-blended reward.
    # None keeps today's ideal simulation bit-for-bit.
    realism: Optional[TraceSpec] = None
    round_spec: Optional[RoundSpec] = None


class FederatedRunner:
    def __init__(self, cfg: RunnerConfig, *,
                 clock: Optional[Callable[[], float]] = None):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.rng = rng
        data = make_dataset(cfg.dataset, seed=cfg.seed,
                            train_size=cfg.train_size,
                            test_size=cfg.eval_size)
        self.spec = data["spec"]
        self.x_train, self.y_train = data["x_train"], data["y_train"]
        self.x_test, self.y_test = data["x_test"], data["y_test"]
        self.shards = partition_non_iid(self.y_train, cfg.num_clients,
                                        cfg.sigma, seed=cfg.seed)
        self.shard_sizes = np.array([len(s) for s in self.shards], np.float32)

        key = jax.random.PRNGKey(cfg.seed)
        self.global_params = cnn_init(
            key, in_channels=self.spec.channels,
            num_classes=self.spec.num_classes,
            image_size=self.spec.image_size)
        self.embedder = WeightEmbedder(self.global_params,
                                       dim=cfg.embed_dim, seed=cfg.seed)
        self.client_embeds = np.zeros((cfg.num_clients, cfg.embed_dim),
                                      np.float32)
        kw = dict(cfg.policy_kwargs or {})
        if cfg.policy in ("dqre_sc", "stratified"):
            kw.setdefault("num_clusters", cfg.num_clusters)
            kw.setdefault("use_pallas", cfg.use_pallas)
            kw.setdefault("approx_method", cfg.approx_method)
            kw.setdefault("num_landmarks", cfg.num_landmarks)
            kw.setdefault("landmarks", cfg.landmarks)
            kw.setdefault("warm_start", cfg.warm_start)
        if cfg.policy in ("dqre_sc", "favor"):
            sched = dict(eps_start=cfg.eps_start, eps_end=cfg.eps_end,
                         eps_decay_steps=cfg.eps_decay_steps)
            sched.update(kw.get("dqn_overrides") or {})
            kw["dqn_overrides"] = sched
        self.policy = make_policy(cfg.policy, cfg.num_clients,
                                  cfg.clients_per_round, cfg.embed_dim,
                                  seed=cfg.seed, **kw)
        self.prev_acc = 0.0
        self.round_idx = 0
        self.history: List[RoundResult] = []
        self._warmed_up = False
        # injectable clock behind RoundResult.timings: host perf_counter
        # by default, the simulated clock once a trace is attached (so
        # timings are bit-identical across replays of the same trace)
        self.sim_clock: Optional[SimClock] = None
        self.trace: Optional[ClientTrace] = None
        self.round_spec = cfg.round_spec or RoundSpec()
        self._clock: Callable[[], float] = clock or time.perf_counter
        if cfg.realism is not None:
            self.attach_trace(
                ClientTrace(cfg.num_clients, cfg.realism, seed=cfg.seed),
                cfg.round_spec)

    def attach_trace(self, trace: ClientTrace,
                     spec: Optional[RoundSpec] = None) -> None:
        """Enable client realism: fault-inject rounds from ``trace``.

        Must be called before any round runs (benchmarks use it to pass
        traces whose per-client tier/phase assignments are derived from
        the runner's own data partition).  Switches the timing clock to
        an owned :class:`SimClock` so every recorded time is simulated.
        """
        if self.round_idx or self.history:
            raise RuntimeError("attach_trace: rounds already ran")
        if trace.num_clients != self.cfg.num_clients:
            raise ValueError(
                f"trace covers {trace.num_clients} clients but the "
                f"runner simulates {self.cfg.num_clients}")
        self.trace = trace
        if spec is not None:
            self.round_spec = spec
        self.sim_clock = SimClock()
        self._clock = self.sim_clock

    # ------------------------------------------------------------------
    def _client_batches(self, client_ids):
        c = self.cfg
        xs, ys = [], []
        for cid in client_ids:
            idx = self.rng.choice(self.shards[cid],
                                  size=c.local_steps * c.batch_size,
                                  replace=True)
            xs.append(self.x_train[idx].reshape(
                c.local_steps, c.batch_size, *self.x_train.shape[1:]))
            ys.append(self.y_train[idx].reshape(c.local_steps, c.batch_size))
        return np.stack(xs), np.stack(ys)

    def _train_cohort(self, client_ids):
        xs, ys = self._client_batches(client_ids)
        rngs = jax.random.split(jax.random.PRNGKey(
            self.cfg.seed * 100_003 + self.round_idx), len(client_ids))
        return local_train_cohort(self.global_params, xs, ys, rngs,
                                  lr=self.cfg.lr)

    def warmup(self):
        """One local pass on EVERY client to initialize the weight-state
        embeddings (FAVOR's initialization round; paper §3.4)."""
        ids = np.arange(self.cfg.num_clients)
        for lo in range(0, len(ids), 32):          # chunk to bound memory
            chunk = ids[lo: lo + 32]
            stacked, _ = self._train_cohort(chunk)
            self.client_embeds[chunk] = weight_delta_embedding(
                self.embedder, stacked, self.global_params)
        self._warmed_up = True

    def _round_state(self) -> RoundState:
        return RoundState(self.round_idx, self.client_embeds.copy(),
                          self.embedder(self.global_params),
                          self.prev_acc)

    # ------------------------------------------------------------------
    def run_round(self) -> RoundResult:
        if not self._warmed_up:
            self.warmup()
        c = self.cfg
        # every phase boundary reads the injectable clock: perf_counter
        # by default (monotonic, unaffected by NTP slews), the realism
        # layer's SimClock when a trace is attached — so the recorded
        # timings are simulated, deterministic wall time under realism.
        clock = self._clock
        t0 = clock()
        state = self._round_state()
        selected = np.asarray(self.policy.select(state))
        t_select = clock()

        outcome = None
        survivors = selected
        if self.trace is not None:
            # fault-inject the round: unavailable clients refuse, slow
            # ones miss the deadline, some drop mid-round — only the
            # survivors train, update their embeddings, and aggregate
            # (weights renormalize over them inside fedavg_aggregate)
            outcome = self.trace.simulate_round(
                self.round_idx, self.sim_clock.now(), selected,
                self.round_spec)
            survivors = outcome.completed
            self.sim_clock.advance(outcome.elapsed_s)
        if len(survivors):
            stacked, _ = self._train_cohort(survivors)
            self.client_embeds[survivors] = weight_delta_embedding(
                self.embedder, stacked, self.global_params)
        t_train = clock()
        if len(survivors):
            weights = self.shard_sizes[survivors]
            self.global_params = fedavg_aggregate(stacked, weights)
        t_aggregate = clock()

        acc, loss, _ = evaluate(self.global_params, self.x_test, self.y_test)
        # round boundary: accuracy immediately drives the host-side
        # reward shaping and policy update, so this sync is inherent
        # repro-lint: ignore[jax-blocking-sync]
        acc = float(acc)
        t_evaluate = clock()
        blend = self.round_spec.reward_blend
        if outcome is not None and blend > 0.0:
            reward = blended_reward(acc, c.target_accuracy,
                                    outcome.attainment, blend=blend)
        else:
            reward = favor_reward(acc, c.target_accuracy)
        next_state = self._round_state()
        self.policy.update(state, next_state,
                           Feedback(acc, reward, selected))
        self.prev_acc = acc
        t_update = clock()
        # repro-lint: ignore[jax-blocking-sync] — same round boundary
        res = RoundResult(self.round_idx, acc, float(loss), reward, selected,
                          t_update - t0,
                          timings={"select": t_select - t0,
                                   "train": t_train - t_select,
                                   "aggregate": t_aggregate - t_train,
                                   "evaluate": t_evaluate - t_aggregate,
                                   "update": t_update - t_evaluate},
                          num_completed=len(survivors),
                          num_dropped=(0 if outcome is None
                                       else len(outcome.dropped)),
                          num_stragglers=(0 if outcome is None
                                          else len(outcome.straggler_ids)),
                          sim_seconds=(t_update - t0 if outcome is None
                                       else outcome.elapsed_s),
                          outcome=outcome)
        self.history.append(res)
        self.round_idx += 1
        return res

    def run(self, num_rounds: int, stop_at_target: bool = False):
        for _ in range(num_rounds):
            res = self.run_round()
            if stop_at_target and res.accuracy >= self.cfg.target_accuracy:
                break
        return self.history

    # ------------------------------------------------------------------
    def rounds_to_accuracy(self, target: Optional[float] = None):
        target = target if target is not None else self.cfg.target_accuracy
        for res in self.history:
            if res.accuracy >= target:
                return res.round_idx + 1
        return None

    def sim_seconds_to_accuracy(self, target: Optional[float] = None):
        """Cumulative simulated wall-clock seconds to the target accuracy.

        The realism benchmarks' headline metric: under stragglers or
        dropout a policy can match rounds-to-target yet pay the full
        deadline every round — this metric sees that.  ``None`` if the
        target was never reached.  Without an attached trace the
        per-round ``sim_seconds`` are host-measured seconds.
        """
        target = target if target is not None else self.cfg.target_accuracy
        total = 0.0
        for res in self.history:
            total += res.sim_seconds
            if res.accuracy >= target:
                return total
        return None

    def final_metrics(self) -> dict:
        _, _, logits = evaluate(self.global_params, self.x_test, self.y_test)
        return classification_metrics(self.y_test, np.asarray(logits))
