"""Pallas TPU kernel: blocked flash attention (GQA, causal, sliding window).

TPU adaptation of the FlashAttention schedule: the grid is
(batch·kv_head·group, q_blocks, kv_blocks) with the KV dimension innermost;
online-softmax statistics (m, l) and the output accumulator live in VMEM
scratch and persist across the KV grid steps ("revisiting" pattern).
BlockSpecs tile Q into (BQ, head_dim) and K/V into (BK, head_dim) VMEM
panels — head_dim ≤ 256 for every assigned arch, so a (BQ=256, BK=512)
tile set stays well inside the ~16 MB v5e VMEM while keeping the
score matmul MXU-aligned (multiples of 128).

Causal + sliding-window masking is applied per element, and *entirely
masked KV blocks are skipped* with ``pl.when`` — that is what restores the
2× triangular-FLOP saving the jnp blocked path (models/attention.py)
cannot express (DESIGN.md §3, EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, block_q, block_k, seq_k, num_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # ---- block-level skip: fully-masked KV blocks do no work ------------
    live = k_start < seq_k
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
    if window is not None:
        live = jnp.logical_and(live,
                               k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # (BQ, dh)
        k = k_ref[0].astype(jnp.float32)               # (BK, dh)
        v = v_ref[0].astype(jnp.float32)               # (BK, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kp < seq_k
        if causal:
            mask = jnp.logical_and(mask, kp <= qp)
        if window is not None:
            mask = jnp.logical_and(mask, kp > qp - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                 # (BQ, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           scale=None, block_q: int = 256,
                           block_k: int = 512, interpret: bool = False):
    """q: (B,S,H,dh); k/v: (B,T,K,dv); H = K*G.  Returns (B,S,H,dv)."""
    B, S, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    dv = v.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)

    block_q = min(block_q, max(S, 8))
    block_k = min(block_k, max(T, 8))
    pq, pk = (-S) % block_q, (-T) % block_k
    qq = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kk = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vv = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sq, Tk = S + pq, T + pk

    # (B, S, K, G, dh) -> (B*K*G, S, dh);  (B, T, K, d) -> (B*K, T, d)
    qq = qq.reshape(B, Sq, K, G, dh).transpose(0, 2, 3, 1, 4)
    qq = qq.reshape(B * K * G, Sq, dh)
    kk = kk.transpose(0, 2, 1, 3).reshape(B * K, Tk, dh)
    vv = vv.transpose(0, 2, 1, 3).reshape(B * K, Tk, dv)

    nq, nk = Sq // block_q, Tk // block_k
    kern = functools.partial(
        _flash_kernel, scale=float(scale), causal=causal,
        window=window, block_q=block_q, block_k=block_k, seq_k=T,
        num_kv=nk)
    out = pl.pallas_call(
        kern,
        grid=(B * K * G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, block_k, dv), lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K * G, Sq, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qq, kk, vv)

    out = out.reshape(B, K, G, Sq, dv).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, H, dv)[:, :S]
