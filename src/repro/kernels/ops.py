"""Jitted public wrappers for the Pallas kernels.

Backend selection: on TPU the compiled kernels run natively; elsewhere
(this CPU container) ``interpret=True`` executes the kernel bodies in
Python for correctness validation.  ``set_use_pallas`` flips the model
substrate between the pure-jnp paths and the kernels globally.
"""

from __future__ import annotations

import jax

from repro.kernels.affinity_pallas import (pairwise_sq_dists_pallas,
                                           rbf_affinity_pallas,
                                           rbf_cross_affinity_pallas)
from repro.kernels.flash_attention_pallas import flash_attention_pallas
from repro.kernels.ssd_pallas import ssd_chunk_pallas

_USE_PALLAS = False


def set_use_pallas(flag: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = bool(flag)


def use_pallas() -> bool:
    return _USE_PALLAS


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pairwise_sq_dists(x, y, **kw):
    return pairwise_sq_dists_pallas(x, y, interpret=_interpret(), **kw)


def rbf_affinity(x, gamma, **kw):
    return rbf_affinity_pallas(x, gamma, interpret=_interpret(), **kw)


def rbf_cross_affinity(x, y, gamma, **kw):
    return rbf_cross_affinity_pallas(x, y, gamma, interpret=_interpret(),
                                     **kw)


def flash_attention(q, k, v, **kw):
    return flash_attention_pallas(q, k, v, interpret=_interpret(), **kw)


def ssd_chunk(xdt, cs, Bm, Cm, **kw):
    return ssd_chunk_pallas(xdt, cs, Bm, Cm, interpret=_interpret(), **kw)
