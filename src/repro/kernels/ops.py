"""Jitted public wrappers for the Pallas kernels.

Backend selection: on TPU the compiled kernels run natively; elsewhere
(this CPU container) ``interpret=True`` executes the kernel bodies in
Python for correctness validation.  ``set_use_pallas`` flips the model
substrate between the pure-jnp paths and the kernels globally; the
toggle is lock-guarded (serving threads flip it around probe solves) and
``use_pallas_scoped`` restores the previous value on exit.
"""

from __future__ import annotations

import contextlib
import threading

import jax

from repro.kernels.affinity_pallas import (pairwise_sq_dists_pallas,
                                           rbf_affinity_pallas,
                                           rbf_cross_affinity_pallas)
from repro.kernels.flash_attention_pallas import flash_attention_pallas
from repro.kernels.nystrom_pallas import (nystrom_colsum_pallas,
                                          nystrom_extension_pallas,
                                          nystrom_gram_pallas,
                                          panel_matmul_pallas,
                                          quantized_cross_affinity_pallas)
from repro.kernels.ssd_pallas import ssd_chunk_pallas


class _PallasToggle:
    """Process-wide substrate switch, safe under concurrent serving threads."""

    def __init__(self):
        self._lock = threading.Lock()
        self._flag = False  # guarded-by: _lock

    def get(self) -> bool:
        with self._lock:
            return self._flag

    def swap(self, flag: bool) -> bool:
        """Set the flag, returning the value it replaced (atomically)."""
        with self._lock:
            prev = self._flag
            self._flag = bool(flag)
        return prev


_TOGGLE = _PallasToggle()


def set_use_pallas(flag: bool) -> None:
    _TOGGLE.swap(flag)


def use_pallas() -> bool:
    return _TOGGLE.get()


@contextlib.contextmanager
def use_pallas_scoped(flag: bool = True):
    """Scoped substrate flip: restores the value observed at entry.

    The swap in/out is atomic, but two threads scoping different values
    over the same window still race on the shared flag — per-call
    ``use_pallas=`` arguments are the per-thread mechanism; this is for
    tests and single-threaded tools.
    """
    prev = _TOGGLE.swap(flag)
    try:
        yield
    finally:
        _TOGGLE.swap(prev)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pairwise_sq_dists(x, y, **kw):
    return pairwise_sq_dists_pallas(x, y, interpret=_interpret(), **kw)


def rbf_affinity(x, gamma, **kw):
    return rbf_affinity_pallas(x, gamma, interpret=_interpret(), **kw)


def rbf_cross_affinity(x, y, gamma, **kw):
    return rbf_cross_affinity_pallas(x, y, gamma, interpret=_interpret(),
                                     **kw)


def nystrom_colsum(x, z, gamma, mask=None, **kw):
    return nystrom_colsum_pallas(x, z, gamma, mask,
                                 interpret=_interpret(), **kw)


def nystrom_gram(x, z, gamma, u, w_isqrt, mask=None, **kw):
    return nystrom_gram_pallas(x, z, gamma, u, w_isqrt, mask,
                               interpret=_interpret(), **kw)


def nystrom_extension(x, z, gamma, u, proj, mask=None, **kw):
    return nystrom_extension_pallas(x, z, gamma, u, proj, mask,
                                    interpret=_interpret(), **kw)


def panel_matmul(w, q, **kw):
    return panel_matmul_pallas(w, q, interpret=_interpret(), **kw)


def quantized_cross_affinity(x, y, gamma, **kw):
    return quantized_cross_affinity_pallas(x, y, gamma,
                                           interpret=_interpret(), **kw)


def flash_attention(q, k, v, **kw):
    return flash_attention_pallas(q, k, v, interpret=_interpret(), **kw)


def ssd_chunk(xdt, cs, Bm, Cm, **kw):
    return ssd_chunk_pallas(xdt, cs, Bm, Cm, interpret=_interpret(), **kw)
