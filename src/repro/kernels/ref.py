"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated
against (tests sweep shapes/dtypes and ``assert_allclose``).  These are
deliberately naive — O(n²) materialization is fine here; the kernels
exist precisely because the naive forms don't scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def pairwise_sq_dists_ref(x, y):
    """(n, d), (m, d) -> (n, m) squared euclidean distances, f32."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    diff = x[:, None, :] - y[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def rbf_affinity_ref(x, gamma: float):
    """exp(-gamma * d2) with zero diagonal (spectral-clustering affinity)."""
    d2 = pairwise_sq_dists_ref(x, x)
    a = jnp.exp(-gamma * d2)
    return a * (1.0 - jnp.eye(x.shape[0], dtype=a.dtype))


def rbf_cross_affinity_ref(x, y, gamma: float):
    """Rectangular exp(-gamma * d2(x, y)) — Nyström cross-affinity block."""
    return jnp.exp(-gamma * pairwise_sq_dists_ref(x, y))


def _quantized_points_ref(a, affinity_dtype: str):
    """The (de)quantized operand the tile math actually dots.

    Per-row symmetric scales (int8) / bf16 rounding — row-wise, so the
    result is independent of how the kernels partition rows into tiles.
    """
    a = a.astype(jnp.float32)
    if affinity_dtype == "f32":
        return a
    if affinity_dtype == "bf16":
        return a.astype(jnp.bfloat16).astype(jnp.float32)
    if affinity_dtype == "int8":
        scale = jnp.maximum(
            jnp.max(jnp.abs(a), axis=-1, keepdims=True) / 127.0, 1e-8)
        return jnp.clip(jnp.round(a / scale), -127.0, 127.0) * scale
    raise ValueError(f"unknown affinity_dtype {affinity_dtype!r}")


def quantized_cross_affinity_ref(x, y, gamma, *, affinity_dtype="f32"):
    """Cross-affinity on the quantized points: the fused-tile ground truth.

    Exactly :func:`rbf_cross_affinity_ref` evaluated at the rounded
    operands, which is what per-row-scale quantization with exact (int32
    / f32-accumulated) dots computes.
    """
    xq = _quantized_points_ref(x, affinity_dtype)
    yq = _quantized_points_ref(y, affinity_dtype)
    return jnp.exp(-gamma * pairwise_sq_dists_ref(xq, yq))


def _masked_c_ref(x, z, gamma, mask, affinity_dtype):
    c = quantized_cross_affinity_ref(x, z, gamma,
                                     affinity_dtype=affinity_dtype)
    if mask is not None:
        c = c * jnp.asarray(mask, jnp.float32).reshape(-1)[:, None]
    return c


def nystrom_colsum_ref(x, z, gamma, mask=None, *, affinity_dtype="f32"):
    """Oracle for ``nystrom_colsum_pallas``: col = Σᵢ C_ij (masked rows drop)."""
    return jnp.sum(_masked_c_ref(x, z, gamma, mask, affinity_dtype), axis=0)


def _s_ref(c, u):
    d_hat = c @ jnp.asarray(u, jnp.float32).reshape(-1)
    return c * jax.lax.rsqrt(jnp.maximum(d_hat, 1e-12))[:, None]


def nystrom_gram_ref(x, z, gamma, u, w_isqrt, mask=None, *,
                     affinity_dtype="f32"):
    """Oracle for ``nystrom_gram_pallas``: W⁻¹ᐟ² (SᵀS) W⁻¹ᐟ², materialized."""
    s = _s_ref(_masked_c_ref(x, z, gamma, mask, affinity_dtype), u)
    w_isqrt = jnp.asarray(w_isqrt, jnp.float32)
    return w_isqrt @ (s.T @ s) @ w_isqrt


def nystrom_extension_ref(x, z, gamma, u, proj, mask=None, *,
                          affinity_dtype="f32"):
    """Oracle for ``nystrom_extension_pallas``: row_normalize(S @ proj)."""
    s = _s_ref(_masked_c_ref(x, z, gamma, mask, affinity_dtype), u)
    v = s @ jnp.asarray(proj, jnp.float32)
    norm = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True))
    return v / jnp.maximum(norm, 1e-12)


def panel_matmul_ref(w, q):
    """Oracle for ``panel_matmul_pallas``: the plain f32 matmul."""
    return w.astype(jnp.float32) @ q.astype(jnp.float32)


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """Naive GQA attention.  q: (B,S,H,d), k/v: (B,T,K,dv)."""
    B, S, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    qf = q.astype(jnp.float32).reshape(B, S, K, G, dh)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qf, kf) * scale
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, v.shape[-1]).astype(v.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """Oracle for ``flash_attention_pallas``: same math, no tiling.

    The blocked online-softmax schedule is an implementation detail —
    semantically the kernel IS naive GQA attention, so the oracle
    delegates to :func:`attention_ref`.
    """
    return attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def ssd_chunk_ref(xdt, cs, Bm, Cm):
    """Intra-chunk SSD reference (what the Pallas kernel computes).

    xdt: (B, c, Q, H, P)   inputs pre-multiplied by dt
    cs:  (B, c, Q, H)      cumulative sum of dt*A within each chunk
    Bm:  (B, c, Q, G, N)   input projections
    Cm:  (B, c, Q, G, N)   output projections,  heads grouped H = G*R

    Returns (y_diag (B,c,Q,H,P), states (B,c,H,P,N)).
    """
    B, c, Q, H, P = xdt.shape
    G = Bm.shape[3]
    R = H // G
    f32 = jnp.float32
    x_g = xdt.reshape(B, c, Q, G, R, P).astype(f32)
    cs_g = cs.reshape(B, c, Q, G, R).astype(f32)
    att = jnp.einsum("bcqgn,bclgn->bcgql", Cm.astype(f32), Bm.astype(f32))
    diff = cs_g[:, :, :, :, :, None] - jnp.moveaxis(cs_g, 2, -1)[:, :, None]
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, None, None, :]
    ldec = jnp.where(mask, jnp.exp(diff), 0.0)
    m = jnp.einsum("bcgql,bcqgrl->bcqgrl", att, ldec)
    y_diag = jnp.einsum("bcqgrl,bclgrp->bcqgrp", m, x_g)
    decay_last = jnp.exp(cs_g[:, :, -1:] - cs_g)
    states = jnp.einsum("bcqgn,bcqgr,bcqgrp->bcgrpn", Bm.astype(f32),
                        decay_last, x_g)
    return (y_diag.reshape(B, c, Q, H, P),
            states.reshape(B, c, H, P, Bm.shape[-1]))
