"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated
against (tests sweep shapes/dtypes and ``assert_allclose``).  These are
deliberately naive — O(n²) materialization is fine here; the kernels
exist precisely because the naive forms don't scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def pairwise_sq_dists_ref(x, y):
    """(n, d), (m, d) -> (n, m) squared euclidean distances, f32."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    diff = x[:, None, :] - y[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def rbf_affinity_ref(x, gamma: float):
    """exp(-gamma * d2) with zero diagonal (spectral-clustering affinity)."""
    d2 = pairwise_sq_dists_ref(x, x)
    a = jnp.exp(-gamma * d2)
    return a * (1.0 - jnp.eye(x.shape[0], dtype=a.dtype))


def rbf_cross_affinity_ref(x, y, gamma: float):
    """Rectangular exp(-gamma * d2(x, y)) — Nyström cross-affinity block."""
    return jnp.exp(-gamma * pairwise_sq_dists_ref(x, y))


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """Naive GQA attention.  q: (B,S,H,d), k/v: (B,T,K,dv)."""
    B, S, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    qf = q.astype(jnp.float32).reshape(B, S, K, G, dh)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bkgst", qf, kf) * scale
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, v.shape[-1]).astype(v.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """Oracle for ``flash_attention_pallas``: same math, no tiling.

    The blocked online-softmax schedule is an implementation detail —
    semantically the kernel IS naive GQA attention, so the oracle
    delegates to :func:`attention_ref`.
    """
    return attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def ssd_chunk_ref(xdt, cs, Bm, Cm):
    """Intra-chunk SSD reference (what the Pallas kernel computes).

    xdt: (B, c, Q, H, P)   inputs pre-multiplied by dt
    cs:  (B, c, Q, H)      cumulative sum of dt*A within each chunk
    Bm:  (B, c, Q, G, N)   input projections
    Cm:  (B, c, Q, G, N)   output projections,  heads grouped H = G*R

    Returns (y_diag (B,c,Q,H,P), states (B,c,H,P,N)).
    """
    B, c, Q, H, P = xdt.shape
    G = Bm.shape[3]
    R = H // G
    f32 = jnp.float32
    x_g = xdt.reshape(B, c, Q, G, R, P).astype(f32)
    cs_g = cs.reshape(B, c, Q, G, R).astype(f32)
    att = jnp.einsum("bcqgn,bclgn->bcgql", Cm.astype(f32), Bm.astype(f32))
    diff = cs_g[:, :, :, :, :, None] - jnp.moveaxis(cs_g, 2, -1)[:, :, None]
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, None, None, :]
    ldec = jnp.where(mask, jnp.exp(diff), 0.0)
    m = jnp.einsum("bcgql,bcqgrl->bcqgrl", att, ldec)
    y_diag = jnp.einsum("bcqgrl,bclgrp->bcqgrp", m, x_g)
    decay_last = jnp.exp(cs_g[:, :, -1:] - cs_g)
    states = jnp.einsum("bcqgn,bcqgr,bcqgrp->bcgrpn", Bm.astype(f32),
                        decay_last, x_g)
    return (y_diag.reshape(B, c, Q, H, P),
            states.reshape(B, c, H, P, Bm.shape[-1]))
