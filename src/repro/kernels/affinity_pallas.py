"""Pallas TPU kernel: blocked pairwise squared distances / RBF affinity.

The O(n²d) hotspot of the paper's spectral clustering (Algorithm I).  TPU
adaptation: the distance matrix is computed as ‖x‖² + ‖y‖² − 2·x·yᵀ so the
inner product runs on the MXU; the grid tiles the output into
(BM, BN) = (128, 128) VMEM blocks (MXU-aligned), each grid cell reading a
(BM, d) row-panel of x and a (BN, d) panel of y.  The RBF variant fuses
exp(−γ·d²) and the zero diagonal into the same kernel so the n×n distance
matrix is never re-read from HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)            # (BM, d)
    y = y_ref[...].astype(jnp.float32)            # (BN, d)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o_ref[...] = jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def _rbf_kernel(x_ref, y_ref, g_ref, o_ref, *, block_m, block_n):
    i = pl.program_id(0)
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    gamma = g_ref[0, 0]
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    a = jnp.exp(-gamma * d2)
    # fused zero diagonal (affinity convention)
    rows = i * block_m + jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    cols = j * block_n + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    o_ref[...] = jnp.where(rows == cols, 0.0, a)


def _cross_rbf_kernel(x_ref, y_ref, g_ref, o_ref):
    """Rectangular fused RBF: no diagonal convention (x and y differ)."""
    x = x_ref[...].astype(jnp.float32)            # (BM, d)
    y = y_ref[...].astype(jnp.float32)            # (BN, d)
    gamma = g_ref[0, 0]
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    o_ref[...] = jnp.exp(-gamma * d2)


def _pad_rows(a, mult):
    pad = (-a.shape[0]) % mult
    return (jnp.pad(a, ((0, pad), (0, 0))), pad) if pad else (a, 0)


def _col_block(m, block_n):
    """Landmark-axis block: never pad a small m up to a full 128 block.

    m < block_n would round a 65-landmark solve up to 128 columns — ~2×
    wasted kernel work on sliced-off lanes.  Cap the block at m rounded
    to the 8-sublane granule instead.
    """
    return min(block_n, -(-m // 8) * 8)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def pairwise_sq_dists_pallas(x, y, *, block_m: int = 128, block_n: int = 128,
                             interpret: bool = False):
    """(n, d), (m, d) -> (n, m) squared distances, f32."""
    n, d = x.shape
    m = y.shape[0]
    block_n = _col_block(m, block_n)
    xp, _ = _pad_rows(x, block_m)
    yp, _ = _pad_rows(y, block_n)
    grid = (xp.shape[0] // block_m, yp.shape[0] // block_n)
    out = pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((block_n, d), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:n, :m]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def rbf_affinity_pallas(x, gamma, *, block_m: int = 128, block_n: int = 128,
                        interpret: bool = False):
    """Fused RBF affinity exp(-gamma d²) with zero diagonal.  (n,d)->(n,n)."""
    n, d = x.shape
    xp, _ = _pad_rows(x, block_m)
    yp, _ = _pad_rows(x, block_n)
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    grid = (xp.shape[0] // block_m, yp.shape[0] // block_n)
    kern = functools.partial(_rbf_kernel, block_m=block_m, block_n=block_n)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, yp, gamma_arr)
    return out[:n, :n]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def rbf_cross_affinity_pallas(x, y, gamma, *, block_m: int = 128,
                              block_n: int = 128, interpret: bool = False):
    """Rectangular fused RBF exp(-gamma d²(x, y)).  (n,d),(m,d) -> (n,m).

    The Nyström landmark path's hotspot: the (N, m) cross-affinity between
    all N clients and m ≪ N landmarks.  Same (BM, BN) output tiling as the
    square affinity kernel; no zero-diagonal (rows and columns index
    different point sets).
    """
    n, d = x.shape
    m = y.shape[0]
    block_n = _col_block(m, block_n)
    xp, _ = _pad_rows(x, block_m)
    yp, _ = _pad_rows(y, block_n)
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    grid = (xp.shape[0] // block_m, yp.shape[0] // block_n)
    out = pl.pallas_call(
        _cross_rbf_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]),
                                       jnp.float32),
        interpret=interpret,
    )(xp, yp, gamma_arr)
    return out[:n, :m]
