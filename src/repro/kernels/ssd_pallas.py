"""Pallas TPU kernel: Mamba2 SSD intra-chunk block (per-chunk dual form).

TPU adaptation (DESIGN.md §3): the GPU reference implements the SSD scan
with warp-level parallel prefix; the MXU-friendly dual form instead
computes, per (batch, chunk, head) grid cell and entirely in VMEM:

    att     = C · Bᵀ                      (Q,Q)  MXU matmul
    M       = att ⊙ exp(cs_i − cs_j)·1[i≥j]      masked decay
    y_diag  = M · x·dt                    (Q,P)  MXU matmul
    state   = (B ⊙ exp(cs_Q − cs)·dt·x)ᵀ contraction -> (P,N)

The sequential inter-chunk recurrence (tiny: (H,P,N) per step) and the
off-diagonal term stay in jnp (``models/mamba.py``) — they are O(S/Q)
work, not the hotspot.  Oracle: ``ref.ssd_chunk_ref``.

Block sizes: Q = chunk length (128/256), P = head_dim 64, N = state 128 —
a (Q=256, N=128, P=64) cell uses ~1 MB of VMEM, far under the 16 MB v5e
budget, and every matmul dim is a multiple of 64/128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(c_ref, b_ref, x_ref, cs_ref, y_ref, st_ref):
    c = c_ref[0, 0, :, 0].astype(jnp.float32)          # (Q, N)
    b = b_ref[0, 0, :, 0].astype(jnp.float32)          # (Q, N)
    x = x_ref[0, 0, :, 0].astype(jnp.float32)          # (Q, P)
    cs = cs_ref[0, 0, :, 0].astype(jnp.float32)        # (Q,)
    Q = x.shape[0]

    att = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Q,Q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.exp(cs[:, None] - cs[None, :])
    m = jnp.where(rows >= cols, att * decay, 0.0)
    y_ref[0, 0, :, 0] = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    decay_last = jnp.exp(cs[-1] - cs)                  # (Q,)
    bw = b * decay_last[:, None]                       # (Q, N)
    st_ref[0, 0, 0] = jax.lax.dot_general(
        x, bw, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (P, N)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(xdt, cs, Bm, Cm, *, interpret: bool = False):
    """Intra-chunk SSD.  Shapes as in ``ref.ssd_chunk_ref``:

    xdt (B,c,Q,H,P), cs (B,c,Q,H), Bm/Cm (B,c,Q,G,N) with H = G*R.
    Returns (y_diag (B,c,Q,H,P) f32, states (B,c,H,P,N) f32).
    """
    B, c, Q, H, P = xdt.shape
    G, N = Bm.shape[3], Bm.shape[4]
    grid = (B, c, H)

    y, st = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            # C/B indexed by the head's group
            pl.BlockSpec((1, 1, Q, 1, N),
                         lambda b, ci, h, R=H // G: (b, ci, 0, h // R, 0)),
            pl.BlockSpec((1, 1, Q, 1, N),
                         lambda b, ci, h, R=H // G: (b, ci, 0, h // R, 0)),
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, ci, h: (b, ci, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, ci, h: (b, ci, 0, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, ci, h: (b, ci, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, ci, h: (b, ci, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, c, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, c, H, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(Cm, Bm, xdt, cs)
    return y, st
