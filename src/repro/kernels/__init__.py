"""Pallas TPU kernels for the framework's compute hot-spots.

Layout per kernel: ``<name>_pallas.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jitted wrappers with backend selection), ``ref.py``
(pure-jnp oracles the tests assert against).

Kernels:
  * affinity_pallas        -- pairwise distances / fused RBF affinity
                              (spectral clustering hotspot, Algorithm I)
  * nystrom_pallas         -- streaming fused Nyström passes (colsum /
                              Gram / extension: the (N, m) cross-affinity
                              never hits HBM) + quantized f32/bf16/int8
                              affinity tiles + eigensolver panel matmul
  * flash_attention_pallas -- blocked online-softmax GQA attention
  * ssd_pallas             -- Mamba2 SSD intra-chunk dual form
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
