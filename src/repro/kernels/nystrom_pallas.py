"""Streaming fused Nyström pipeline: C→S→SᵀS with no (N, m) in HBM.

``cohort/nystrom.py::_nystrom_core`` composes the landmark extension from
jnp ops around one Pallas affinity kernel, which materializes the (N, m)
cross-affinity C and re-reads it from HBM three more times (column sum,
degree scaling, SᵀS, extension).  At N = 10⁵–10⁸ the select is memory-
bound, so these kernels recompute the C tile from the (block_m, d) row
panel each pass instead of ever writing it out — three grid sweeps over
row panels, each tile living and dying in VMEM:

1. ``nystrom_colsum_pallas``   — affinity tile + column sum, accumulating
   ``col = Σᵢ C_ij`` into a single (1, m) output block.
2. ``nystrom_gram_pallas``     — recompute the tile, apply the
   ``rsqrt(d̂)`` degree scaling in-register (``d̂ = C·u`` folds the
   m-sized ``u = W⁻¹ᐟ²(W⁻¹ᐟ² col)`` the caller derives from pass 1),
   accumulate the (m, m) ``SᵀS`` Gram across the grid, and rotate by
   ``W⁻¹ᐟ²`` on the LAST grid step only — rotation is linear, so the
   per-shard ``psum`` composition of ``cohort/sharded.py`` is unchanged:
   ``psum(W⁻¹ᐟ² SᵀS_s W⁻¹ᐟ²) = W⁻¹ᐟ² (Σ_s SᵀS_s) W⁻¹ᐟ²``.
3. ``nystrom_extension_pallas`` — recompute the tile a third time and
   emit the row-normalized embedding ``V = S · proj`` directly, where
   ``proj = (W⁻¹ᐟ² U)·rsqrt(λ)`` is the precomputed (m, k) projector.

FLOPs triple on the affinity tile (recomputed 3×) but HBM traffic drops
from ~7 (N, m) transfers to the (N, d) input read per pass — the right
trade on every memory-bound backend.

Quantized affinity (the AQT idiom): ``affinity_dtype`` selects the tile
matmul precision — ``"f32"`` (exact), ``"bf16"`` (bf16 operands, f32 MXU
accumulation), or ``"int8"`` (per-ROW amax/127 scales so the quantization
grid is independent of the tile partition, int8×int8→int32 MXU dot,
rescale by ``s_x·s_zᵀ``).  Row norms are taken from the same (de)quantized
operands as the cross term so d² stays a true squared distance (≥ 0).

Every wrapper takes ``interpret=`` (CPU CI runs the kernels in interpret
mode) and has a matching ``*_ref`` oracle in ``kernels/ref.py``.

A zero/one row ``mask`` (n,) input covers both the wrapper's own row
padding and the global padding of the ``shard_map`` path: a masked row
contributes a zero row of C, hence nothing to ``col`` or ``SᵀS``, and a
zero (later sliced-off) row of V.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12      # degree / row-norm floor — matches cohort/nystrom.py
_QEPS = 1e-8      # int8 scale floor for all-zero rows

AFFINITY_DTYPES = ("f32", "bf16", "int8")


def _quantize_rows(a):
    """Per-row symmetric int8 quantization: (values, scales (rows, 1))."""
    scale = jnp.maximum(jnp.max(jnp.abs(a), axis=-1, keepdims=True) / 127.0,
                        _QEPS)
    q = jnp.clip(jnp.round(a / scale), -127.0, 127.0)
    return q, scale


def _affinity_tile(x, z, gamma, affinity_dtype: str):
    """One (bm, bn) RBF cross-affinity tile at the requested precision.

    Same formula as ``affinity_pallas._cross_rbf_kernel``:
    exp(-γ·max(‖x‖² + ‖z‖² − 2·x·zᵀ, 0)), f32 output.  For quantized
    dtypes the norms are computed from the SAME rounded operands as the
    cross term, so d² is the exact squared distance of the quantized
    points (never negative by construction).
    """
    x = x.astype(jnp.float32)
    z = z.astype(jnp.float32)
    if affinity_dtype == "f32":
        xc, zc = x, z
        xy = jax.lax.dot_general(x, z, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    elif affinity_dtype == "bf16":
        xb = x.astype(jnp.bfloat16)
        zb = z.astype(jnp.bfloat16)
        xc = xb.astype(jnp.float32)
        zc = zb.astype(jnp.float32)
        xy = jax.lax.dot_general(xb, zb, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    elif affinity_dtype == "int8":
        qx, sx = _quantize_rows(x)                 # (bm, d), (bm, 1)
        qz, sz = _quantize_rows(z)                 # (bn, d), (bn, 1)
        acc = jax.lax.dot_general(qx.astype(jnp.int8), qz.astype(jnp.int8),
                                  (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.int32)
        xy = acc.astype(jnp.float32) * (sx * sz.T)
        xc = qx * sx
        zc = qz * sz
    else:
        raise ValueError(f"unknown affinity_dtype {affinity_dtype!r}; "
                         f"expected one of {AFFINITY_DTYPES}")
    xx = jnp.sum(xc * xc, axis=-1)[:, None]
    zz = jnp.sum(zc * zc, axis=-1)[None, :]
    d2 = jnp.maximum(xx + zz - 2.0 * xy, 0.0)
    return jnp.exp(-gamma * d2)


def _s_tile(c, u):
    """Degree-normalized tile S = C·rsqrt(max(C·u, eps)) in-register."""
    d_hat = jax.lax.dot_general(c, u, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bm,1)
    return c * jax.lax.rsqrt(jnp.maximum(d_hat, _EPS))


# --------------------------------------------------------------------------
# pass 1: fused affinity + column sum
# --------------------------------------------------------------------------

def _colsum_kernel(x_ref, z_ref, g_ref, mask_ref, o_ref, *, affinity_dtype):
    i = pl.program_id(0)
    c = _affinity_tile(x_ref[...], z_ref[...], g_ref[0, 0], affinity_dtype)
    c = c * mask_ref[...]                                  # (bm, 1) bcast
    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    o_ref[...] += jnp.sum(c, axis=0, keepdims=True)        # (1, m)


# --------------------------------------------------------------------------
# pass 2: fused affinity + degree scaling + SᵀS Gram (+ last-step rotation)
# --------------------------------------------------------------------------

def _gram_kernel(x_ref, z_ref, g_ref, u_ref, wis_ref, mask_ref, o_ref, *,
                 affinity_dtype):
    i = pl.program_id(0)
    c = _affinity_tile(x_ref[...], z_ref[...], g_ref[0, 0], affinity_dtype)
    c = c * mask_ref[...]
    s = _s_tile(c, u_ref[...])
    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    o_ref[...] += jax.lax.dot_general(s, s, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    # W⁻¹ᐟ² rotation once, on the final accumulated Gram — linear, so the
    # sharded psum over per-shard outputs still composes (see module doc)
    @pl.when(i == pl.num_programs(0) - 1)
    def _rotate():
        wis = wis_ref[...]
        o_ref[...] = jax.lax.dot_general(
            jax.lax.dot_general(wis, o_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32),
            wis, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# pass 3: fused affinity + degree scaling + projection + row normalization
# --------------------------------------------------------------------------

def _extension_kernel(x_ref, z_ref, g_ref, u_ref, proj_ref, mask_ref, o_ref,
                      *, affinity_dtype):
    c = _affinity_tile(x_ref[...], z_ref[...], g_ref[0, 0], affinity_dtype)
    c = c * mask_ref[...]
    s = _s_tile(c, u_ref[...])
    v = jax.lax.dot_general(s, proj_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bm, k)
    norm = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True))
    o_ref[...] = v / jnp.maximum(norm, _EPS)


# --------------------------------------------------------------------------
# eigensolver row-panel matmul (subspace sweeps)
# --------------------------------------------------------------------------

def _panel_matmul_kernel(w_ref, q_ref, o_ref):
    o_ref[...] = jax.lax.dot_general(
        w_ref[...], q_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _quant_cross_kernel(x_ref, y_ref, g_ref, o_ref, *, affinity_dtype):
    o_ref[...] = _affinity_tile(x_ref[...], y_ref[...], g_ref[0, 0],
                                affinity_dtype)


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _row_block(n: int, block_m: int) -> int:
    """Effective row-panel height: never pad small n up to a huge panel."""
    return min(block_m, _round_up(max(n, 1), 8))


def _pad_rows_mask(x, mask, bm):
    """Pad rows to a ``bm`` multiple; padded mask entries are zero."""
    n = x.shape[0]
    if mask is None:
        mask = jnp.ones((n,), jnp.float32)
    mask = jnp.asarray(mask, jnp.float32).reshape(n, 1)
    pad = (-n) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    return x, mask


@functools.partial(jax.jit, static_argnames=("affinity_dtype", "block_m",
                                             "interpret"))
def nystrom_colsum_pallas(x, z, gamma, mask=None, *,
                          affinity_dtype: str = "f32", block_m: int = 1024,
                          interpret: bool = False):
    """Fused ``col = Σᵢ exp(-γ d²(xᵢ, z))·maskᵢ`` without materializing C.

    x: (n, d) rows, z: (m, d) landmarks, mask: optional (n,) zero/one
    rows.  Returns (m,) f32.  The (block_m, m) affinity tile exists only
    in VMEM.
    """
    n = x.shape[0]
    m = z.shape[0]
    bm = _row_block(n, block_m)
    xp, maskp = _pad_rows_mask(x, mask, bm)
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    kern = functools.partial(_colsum_kernel, affinity_dtype=affinity_dtype)
    d = x.shape[1]
    out = pl.pallas_call(
        kern,
        grid=(xp.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((m, d), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.float32),
        interpret=interpret,
    )(xp, z, gamma_arr, maskp)
    return out[0]


@functools.partial(jax.jit, static_argnames=("affinity_dtype", "block_m",
                                             "interpret"))
def nystrom_gram_pallas(x, z, gamma, u, w_isqrt, mask=None, *,
                        affinity_dtype: str = "f32", block_m: int = 1024,
                        interpret: bool = False):
    """Fused ``W⁻¹ᐟ² (SᵀS) W⁻¹ᐟ²`` where S is the degree-normalized C.

    ``u`` (m,) is ``W⁻¹ᐟ²(W⁻¹ᐟ² col)`` from pass 1 (globally psummed on
    the sharded path); ``w_isqrt`` (m, m).  Returns the rotated (m, m)
    Gram — symmetrize and eigensolve on the host side.
    """
    n = x.shape[0]
    m = z.shape[0]
    bm = _row_block(n, block_m)
    xp, maskp = _pad_rows_mask(x, mask, bm)
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    u2 = jnp.asarray(u, jnp.float32).reshape(m, 1)
    kern = functools.partial(_gram_kernel, affinity_dtype=affinity_dtype)
    d = x.shape[1]
    out = pl.pallas_call(
        kern,
        grid=(xp.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((m, d), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((m, 1), lambda i: (0, 0)),
                  pl.BlockSpec((m, m), lambda i: (0, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((m, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=interpret,
    )(xp, z, gamma_arr, u2, jnp.asarray(w_isqrt, jnp.float32), maskp)
    return out


@functools.partial(jax.jit, static_argnames=("affinity_dtype", "block_m",
                                             "interpret"))
def nystrom_extension_pallas(x, z, gamma, u, proj, mask=None, *,
                             affinity_dtype: str = "f32",
                             block_m: int = 1024, interpret: bool = False):
    """Fused row-normalized extension ``row_normalize(S · proj)``.

    ``proj`` (m, k) is ``(W⁻¹ᐟ² U_k)·rsqrt(λ_k)`` — the whole right-hand
    side of the Nyström extension collapsed to one matmul.  Returns
    (n, k) f32 with unit rows (masked/zero rows stay zero).
    """
    n = x.shape[0]
    m = z.shape[0]
    k = proj.shape[1]
    bm = _row_block(n, block_m)
    xp, maskp = _pad_rows_mask(x, mask, bm)
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    u2 = jnp.asarray(u, jnp.float32).reshape(m, 1)
    kern = functools.partial(_extension_kernel,
                             affinity_dtype=affinity_dtype)
    d = x.shape[1]
    out = pl.pallas_call(
        kern,
        grid=(xp.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((m, d), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((m, 1), lambda i: (0, 0)),
                  pl.BlockSpec((m, k), lambda i: (0, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], k), jnp.float32),
        interpret=interpret,
    )(xp, z, gamma_arr, u2, jnp.asarray(proj, jnp.float32), maskp)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def panel_matmul_pallas(w, q, *, block_rows: int = 2048,
                        interpret: bool = False):
    """Row-panel (m, p) @ (p, r) with the panel loop inside one kernel.

    The Pallas twin of ``cohort/eigensolver.py::_blocked_matmul``: the
    subspace sweep's W·Q product evaluated one (block_rows, p) panel at a
    time so peak residency stays O(block_rows·p), without round-tripping
    each panel through a separate XLA dispatch.
    """
    m, p = w.shape
    r = q.shape[1]
    bl = _row_block(m, block_rows)
    pad = (-m) % bl
    wp = jnp.pad(w.astype(jnp.float32), ((0, pad), (0, 0))) if pad \
        else w.astype(jnp.float32)
    out = pl.pallas_call(
        _panel_matmul_kernel,
        grid=(wp.shape[0] // bl,),
        in_specs=[pl.BlockSpec((bl, p), lambda i: (i, 0)),
                  pl.BlockSpec((p, r), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bl, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((wp.shape[0], r), jnp.float32),
        interpret=interpret,
    )(wp, q.astype(jnp.float32))
    return out[:m]


@functools.partial(jax.jit, static_argnames=("affinity_dtype", "block_m",
                                             "interpret"))
def quantized_cross_affinity_pallas(x, y, gamma, *,
                                    affinity_dtype: str = "f32",
                                    block_m: int = 128,
                                    interpret: bool = False):
    """Materialized cross-affinity at a chosen tile precision.

    The m-sized companion of the streaming passes: the fused path builds
    its landmark block W = A(z, z) through the SAME quantized tile math
    (per-row scales make the result partition-independent), keeping W
    bit-consistent with the streamed C tiles.  ``"f32"`` reproduces
    ``rbf_cross_affinity_pallas`` exactly.
    """
    n = x.shape[0]
    m = y.shape[0]
    bm = _row_block(n, block_m)
    xp, _ = _pad_rows_mask(x, None, bm)
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape(1, 1)
    kern = functools.partial(_quant_cross_kernel,
                             affinity_dtype=affinity_dtype)
    d = x.shape[1]
    out = pl.pallas_call(
        kern,
        grid=(xp.shape[0] // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((m, d), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bm, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], m), jnp.float32),
        interpret=interpret,
    )(xp, y, gamma_arr)
    return out[:n]
