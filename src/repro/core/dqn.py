"""Deep-Q network with current + target networks (paper §3.3).

Implements exactly the structure the paper describes: two MLPs — the
*current* Q function and a delayed *target* Q function — trained on the
TD error  ``r + γ·max_a' Q(s',a';θ⁻) − Q(s,a;θ)``  (Double-DQN action
selection optional), ε-greedy exploration, a uniform replay buffer, and
periodic hard target sync ("after a certain number of training
repetitions, a copy is made").
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


@dataclasses.dataclass
class DQNConfig:
    state_dim: int
    num_actions: int
    hidden: Tuple[int, ...] = (128, 128)
    gamma: float = 0.95
    lr: float = 1e-3
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 200
    target_sync_every: int = 10
    buffer_size: int = 4096
    batch_size: int = 64
    double_dqn: bool = True


def qnet_init(key, cfg: DQNConfig):
    dims = (cfg.state_dim, *cfg.hidden, cfg.num_actions)
    keys = jax.random.split(key, len(dims) - 1)
    return [L.dense_init(k, a, b, bias=True, dtype="float32")
            for k, a, b in zip(keys, dims[:-1], dims[1:])]


def qnet_apply(params, s):
    h = s
    for i, p in enumerate(params):
        h = L.dense(p, h)
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


@jax.jit
def _td_loss(params, target_params, batch, gamma, double_dqn):
    s, a, r, s2, done = (batch["s"], batch["a"], batch["r"], batch["s2"],
                         batch["done"])
    q = qnet_apply(params, s)
    q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
    q_next_t = qnet_apply(target_params, s2)
    q_next_c = qnet_apply(params, s2)
    a_star = jnp.where(double_dqn,
                       jnp.argmax(q_next_c, axis=1),
                       jnp.argmax(q_next_t, axis=1))
    q_next = jnp.take_along_axis(q_next_t, a_star[:, None], axis=1)[:, 0]
    target = r + gamma * (1.0 - done) * q_next
    return jnp.mean(jnp.square(q_sa - jax.lax.stop_gradient(target)))


_td_grad = jax.jit(jax.value_and_grad(_td_loss))


class ReplayBuffer:
    """Uniform ring-buffer replay (host-side numpy)."""

    def __init__(self, capacity: int, state_dim: int):
        self.capacity = capacity
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity,), np.int32)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)
        self.size = 0
        self.ptr = 0

    def add(self, s, a, r, s2, done):
        i = self.ptr
        self.s[i], self.a[i], self.r[i] = s, a, r
        self.s2[i], self.done[i] = s2, float(done)
        self.ptr = (i + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.size, size=min(batch, self.size))
        return {"s": jnp.asarray(self.s[idx]), "a": jnp.asarray(self.a[idx]),
                "r": jnp.asarray(self.r[idx]),
                "s2": jnp.asarray(self.s2[idx]),
                "done": jnp.asarray(self.done[idx])}


class DQNAgent:
    """Current + target Q networks with ε-greedy selection."""

    def __init__(self, key, cfg: DQNConfig):
        self.cfg = cfg
        self.params = qnet_init(key, cfg)
        self.target_params = jax.tree.map(jnp.copy, self.params)
        self.buffer = ReplayBuffer(cfg.buffer_size, cfg.state_dim)
        self.steps = 0
        self.train_calls = 0
        # plain SGD-with-momentum on the TD loss
        self.mu = jax.tree.map(jnp.zeros_like, self.params)
        self._last_loss = 0.0              # device scalar after training

    # -- acting -----------------------------------------------------------
    def epsilon(self) -> float:
        c = self.cfg
        frac = min(self.steps / max(c.eps_decay_steps, 1), 1.0)
        return float(c.eps_start + (c.eps_end - c.eps_start) * frac)

    def q_values(self, state) -> np.ndarray:
        return np.asarray(qnet_apply(self.params, jnp.asarray(state)[None])[0])

    def act(self, rng: np.random.Generator, state) -> int:
        self.steps += 1
        if rng.random() < self.epsilon():
            return int(rng.integers(self.cfg.num_actions))
        return int(np.argmax(self.q_values(state)))

    # -- learning ----------------------------------------------------------
    def observe(self, s, a, r, s2, done=False):
        self.buffer.add(np.asarray(s, np.float32), a, r,
                        np.asarray(s2, np.float32), done)

    def train_step(self, rng: np.random.Generator):
        """One TD minibatch; returns the loss as a DEVICE scalar.

        Deliberately no ``float()`` here: the serving path runs this
        under its select lock (``CohortServer.observe_round``), and a
        host sync would stall every concurrent select on device
        compute.  Materialize lazily via :attr:`last_loss` (the stats
        endpoint does).
        """
        if self.buffer.size < 8:
            return 0.0
        batch = self.buffer.sample(rng, self.cfg.batch_size)
        loss, grads = _td_grad(self.params, self.target_params, batch,
                               self.cfg.gamma, self.cfg.double_dqn)
        lr, mom = self.cfg.lr, 0.9
        self.mu = jax.tree.map(lambda m, g: mom * m + g, self.mu, grads)
        self.params = jax.tree.map(lambda p, m: p - lr * m,
                                   self.params, self.mu)
        self.train_calls += 1
        if self.train_calls % self.cfg.target_sync_every == 0:
            self.target_params = jax.tree.map(jnp.copy, self.params)
        self._last_loss = loss
        return loss

    @property
    def last_loss(self) -> float:
        """Most recent TD loss, materialized on demand (syncs here)."""
        return float(self._last_loss)
