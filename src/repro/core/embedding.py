"""Weight embedding: reduce model-weight pytrees to low-dim vectors.

Paper §4.2: "The weights of the model stored by the DQRE feature
extraction section are reduced to two vectors."  FAVOR (Wang et al. 2020)
uses PCA of the flattened weights; we use a fixed Gaussian random
projection (Johnson–Lindenstrauss), which needs no fitting pass, is
deterministic given the seed, and preserves the pairwise distances that
both spectral clustering and the DQN state consume.  An exact (small-d)
PCA is provided for parity experiments.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flatten_pytree(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])


class WeightEmbedder:
    """Fixed random projection  R^{n_params} -> R^{dim}."""

    def __init__(self, template_params, dim: int = 2, seed: int = 0):
        self.dim = dim
        n = int(sum(np.prod(x.shape) for x in jax.tree.leaves(template_params)))
        key = jax.random.PRNGKey(seed)
        # stored as (dim, n) rows; applied blockwise to avoid a giant matmul
        self.proj = jax.random.normal(key, (dim, n), jnp.float32) / np.sqrt(n)
        self._embed = jax.jit(self._embed_impl)

    def _embed_impl(self, params):
        flat = flatten_pytree(params)
        return self.proj @ flat

    def __call__(self, params) -> np.ndarray:
        return np.asarray(self._embed(params))

    def embed_many(self, stacked_params) -> np.ndarray:
        """Params stacked along a leading client axis -> (clients, dim)."""
        return np.asarray(jax.vmap(self._embed_impl)(stacked_params))


def pca_embed(mats: np.ndarray, dim: int = 2) -> np.ndarray:
    """Exact PCA for parity checks.  mats: (n, p) -> (n, dim)."""
    x = mats - mats.mean(axis=0, keepdims=True)
    u, s, _ = np.linalg.svd(x, full_matrices=False)
    return u[:, :dim] * s[:dim]
