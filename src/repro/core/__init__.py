"""The paper's primary contribution: DQRE-SCnet client selection.

Spectral clustering (Algorithm I), the Deep-Q ensemble (current + target
networks), weight embeddings, and the four selection policies (FedAvg /
K-Center / FAVOR baselines + DQRE-SCnet).
"""

from repro.core.spectral import (affinity_matrix, auto_gamma, cross_affinity,
                                 normalized_laplacian,
                                 nystrom_spectral_embedding,
                                 spectral_embedding, spectral_cluster,
                                 eigengap_k)
from repro.core.kmeans import kmeans, pairwise_sq_dists
from repro.core.dqn import DQNAgent, DQNConfig, qnet_init, qnet_apply
from repro.core.embedding import WeightEmbedder, flatten_pytree, pca_embed
from repro.core.selection import (POLICIES, make_policy, favor_reward,
                                  RoundState, Feedback, SelectionPolicy,
                                  RandomSelection, KCenterSelection,
                                  FavorSelection, DQREScSelection)

__all__ = [
    "affinity_matrix", "auto_gamma", "cross_affinity",
    "normalized_laplacian", "nystrom_spectral_embedding",
    "spectral_embedding", "spectral_cluster", "eigengap_k", "kmeans",
    "pairwise_sq_dists",
    "DQNAgent", "DQNConfig", "qnet_init", "qnet_apply",
    "WeightEmbedder", "flatten_pytree", "pca_embed",
    "POLICIES", "make_policy", "favor_reward", "RoundState", "Feedback",
    "SelectionPolicy", "RandomSelection", "KCenterSelection",
    "FavorSelection", "DQREScSelection",
]
