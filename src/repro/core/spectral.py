"""Spectral clustering — Algorithm I of the paper, in JAX.

Steps (verbatim from the paper's pseudo-code):

  A       = affinity matrix (RBF over pairwise distances)
  D       = diag(sum_j A_ij)
  L       = D - A                      (unnormalized Laplacian)
  L_norm  = I - D^{-1/2} A D^{-1/2}    (normalized Laplacian)
  X       = first k eigenvectors of L_norm (smallest eigenvalues)
  Y       = row-normalized X
  cluster rows of Y with k-means; assign point i to cluster of row i.

The affinity computation is the O(n²d) hotspot; ``use_pallas=True`` routes
it through the TPU Pallas kernels (``kernels/affinity_pallas.py``), whose
jnp oracles are in ``kernels/ref.py``.

Two scale regimes:

* ``method="dense"`` — the exact path above.  ``solver="eigh"`` is XLA's
  full eigendecomposition (TPU-native, O(n³)); ``solver="subspace"``
  replaces it with orthogonal (subspace) iteration on 2I − L_norm, which
  only costs O(n²k) per sweep and recovers the same smallest-k invariant
  subspace when k ≪ n.
* ``method="nystrom"`` — the approximate path for cross-device-FL cohort
  sizes (N ~ 10⁵): sample m ≪ N landmarks, compute only the (N, m)
  cross-affinity C and the (m, m) landmark block W, and recover the
  normalized-Laplacian embedding from the one-shot Nyström extension
  (Fowlkes et al., 2004):  Â = D̂^{-1/2} C W⁺ Cᵀ D̂^{-1/2} with
  D̂ = diag(C W⁺ Cᵀ 1).  Everything is O(N·m) memory / O(N m d + m³)
  compute, so N = 100k clients fits where the dense O(N²) matrix cannot.

Also exposes ``eigengap_k`` — the paper's "first large gap" heuristic for
choosing the number of clusters.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans, pairwise_sq_dists

_EPS = 1e-12
# gamma estimation subsamples the distance matrix beyond this many rows —
# the median of a few thousand rows is statistically indistinguishable
# from the full median and avoids sorting 10¹⁰ entries at N = 100k.
_GAMMA_SAMPLE_ROWS = 4096


def auto_gamma(d2):
    """Median heuristic: gamma = 1 / (2 · median of positive distances).

    Uses ``nanmedian`` over the zero-masked matrix — ``jnp.median`` on a
    NaN-masked array returns NaN, which used to silently collapse the
    data-adaptive bandwidth to the 0.5 fallback for *every* input.
    """
    if d2.shape[0] > _GAMMA_SAMPLE_ROWS:
        d2 = d2[:_GAMMA_SAMPLE_ROWS]
    med = jnp.nanmedian(jnp.where(d2 > 0, d2, jnp.nan))
    med = jnp.nan_to_num(med, nan=1.0)
    return 1.0 / jnp.maximum(2.0 * med, _EPS)


def affinity_matrix(x, *, gamma: float | None = None, use_pallas: bool = False):
    """RBF affinity A_ij = exp(-gamma ||x_i - x_j||^2), zero diagonal."""
    if use_pallas:
        from repro.kernels import ops as kops
        d2 = kops.pairwise_sq_dists(x, x)
    else:
        d2 = pairwise_sq_dists(x, x)
    if gamma is None:
        # zero the diagonal first: self-distances are 0 by definition but
        # the matmul form leaves tiny positive junk that would leak past
        # auto_gamma's positive-entry mask and bias the median low.
        eye = jnp.eye(x.shape[0], dtype=d2.dtype)
        gamma = auto_gamma(d2 * (1.0 - eye))
    a = jnp.exp(-gamma * d2)
    return a * (1.0 - jnp.eye(x.shape[0], dtype=a.dtype))


def cross_affinity(x, z, *, gamma, use_pallas: bool = False):
    """Rectangular RBF affinity exp(-gamma ||x_i - z_j||²), (n, m)."""
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.rbf_cross_affinity(x, z, gamma)
    return jnp.exp(-gamma * pairwise_sq_dists(x, z))


def normalized_laplacian(a):
    d = jnp.sum(a, axis=1)
    inv_sqrt = jax.lax.rsqrt(jnp.maximum(d, _EPS))
    n = a.shape[0]
    return jnp.eye(n) - a * inv_sqrt[:, None] * inv_sqrt[None, :]


def row_normalize(x):
    """Rows scaled to unit norm (the Y step of Algorithm I); shared with
    the cohort subsystem's Nyström core."""
    norms = jnp.linalg.norm(x, axis=1, keepdims=True)
    return x / jnp.maximum(norms, _EPS)


_row_normalize = row_normalize


def spectral_embedding(a, k: int, *, solver: str = "eigh",
                       iters: int = 60):
    """First-k eigenvectors of L_norm (ascending eigenvalues), row-normed.

    ``solver="eigh"`` — exact, O(n³).  ``solver="subspace"`` — orthogonal
    iteration on 2I − L_norm (eigenvalues of L_norm lie in [0, 2], so its
    smallest-k subspace is the dominant subspace of the shift), O(n²k·iters),
    followed by a Rayleigh–Ritz rotation; returns only k eigenvalues.
    """
    if solver == "eigh":
        lap = normalized_laplacian(a)
        evals, evecs = jnp.linalg.eigh(lap)        # ascending
        x = evecs[:, :k]
    elif solver == "subspace":
        x, evals = _subspace_smallest_k(a, k, iters=iters)
    else:
        raise ValueError(f"unknown solver: {solver!r}")
    return _row_normalize(x), evals


def _subspace_smallest_k(a, k: int, *, iters: int = 60):
    """Smallest-k eigenpairs of L_norm = I − A_norm without full eigh.

    Orthogonal iteration: Q ← qr(B Q) with B = 2I − L_norm = I + A_norm
    (spd, dominant subspace = smallest-k of L_norm), then a k×k
    Rayleigh–Ritz solve to rotate Q onto the Ritz vectors and recover the
    eigenvalues of L_norm itself.
    """
    n = a.shape[0]
    d = jnp.sum(a, axis=1)
    inv_sqrt = jax.lax.rsqrt(jnp.maximum(d, _EPS))
    a_norm = a * inv_sqrt[:, None] * inv_sqrt[None, :]

    # deterministic range start: subspace iteration converges from any
    # full-rank start, and a fixed key keeps the solver reproducible
    # without plumbing a key through the public API
    # repro-lint: ignore[prng-constant-key]
    q0 = jax.random.normal(jax.random.PRNGKey(0), (n, k), a.dtype)
    q0, _ = jnp.linalg.qr(q0)

    def body(_, q):
        q, _ = jnp.linalg.qr(q + a_norm @ q)       # B q = q + A_norm q
        return q

    q = jax.lax.fori_loop(0, iters, body, q0)
    # Rayleigh-Ritz on L_norm: T = Qᵀ L Q = Qᵀ Q − Qᵀ A_norm Q
    t = q.T @ (q - a_norm @ q)
    t = 0.5 * (t + t.T)
    evals, u = jnp.linalg.eigh(t)                  # ascending
    return q @ u, evals


def nystrom_spectral_embedding(key, x, k: int, num_landmarks: int, *,
                               gamma: float | None = None,
                               use_pallas: bool = False):
    """Approximate normalized-Laplacian embedding via Nyström landmarks.

    Samples m UNIFORM landmarks Z ⊂ x and delegates the one-shot Nyström
    extension (Fowlkes et al., 2004) to the cohort subsystem's
    landmark-explicit core (``repro.cohort.nystrom``):

        D̂ = diag(C W⁺ Cᵀ 1)                approximate degrees
        S  = D̂^{-1/2} C                     degree-normalized cross block
        M  = W^{-1/2} (Sᵀ S) W^{-1/2}       (m, m), symmetric
        Â  = S W⁺ Sᵀ  has eigenvectors  V = S W^{-1/2} U Λ^{-1/2}

    The top-k eigenpairs of Â are the smallest-k of L_norm = I − Â.
    Returns (Y row-normalized (n, k), evals of L_norm ascending (m,)).
    ``key`` fully determines the landmark set: repeated calls with the
    same key are bit-identical.  For non-uniform landmark strategies,
    warm starts, and the sharded path, use ``repro.cohort.CohortEngine``.
    """
    # deferred import: cohort builds on core, not the other way around
    from repro.cohort.nystrom import nystrom_from_landmarks

    n = x.shape[0]
    m = min(int(num_landmarks), n)
    if m < k:
        raise ValueError(f"num_landmarks={m} must be >= k={k}")
    x = x.astype(jnp.float32)
    idx = jax.random.choice(key, n, (m,), replace=False)
    if gamma is None:
        rows = x[:min(n, _GAMMA_SAMPLE_ROWS)]
        gamma = auto_gamma(pairwise_sq_dists(rows, x[idx]))
    y, evals, _, _ = nystrom_from_landmarks(x, idx, k, gamma,
                                            use_pallas=use_pallas)
    return y, evals


def default_num_landmarks(n: int, k: int) -> int:
    return min(n, max(8 * k, 64))


def eigengap_k(evals, max_k: int = 10) -> jnp.ndarray:
    """Paper §3.4: number of eigenvalues before the first large gap."""
    gaps = jnp.diff(evals[: max_k + 1])
    return jnp.argmax(gaps) + 1


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "method",
                                             "num_landmarks", "solver"))
def spectral_cluster(key, x, k: int, *, gamma: float | None = None,
                     use_pallas: bool = False, method: str = "dense",
                     num_landmarks: int | None = None,
                     solver: str = "eigh",
                     landmark_key=None):
    """Full Algorithm I.  x: (n, d) points -> (assignments, Y, evals).

    ``method="dense"`` computes the exact n×n affinity (``solver`` picks
    the eigensolver); ``method="nystrom"`` uses ``num_landmarks`` sampled
    landmarks (default min(n, max(8k, 64))) and scales to n ~ 10⁵.

    Landmark sampling is a pure function of the PRNG key: by default the
    landmark key is split off ``key``; pass ``landmark_key`` to pin the
    landmark set independently of the k-means key (callers that manage
    their own key streams — e.g. the cohort engine — use this so
    repeated calls with the same key are bit-identical).
    """
    km_key, lm_key = jax.random.split(key)
    if landmark_key is not None:
        if method != "nystrom":
            raise ValueError(
                "landmark_key only applies to method='nystrom'")
        lm_key = landmark_key
    if method == "dense":
        if num_landmarks is not None:
            raise ValueError("num_landmarks only applies to method='nystrom'")
        a = affinity_matrix(x, gamma=gamma, use_pallas=use_pallas)
        y, evals = spectral_embedding(a, k, solver=solver)
    elif method == "nystrom":
        if solver != "eigh":
            raise ValueError("solver only applies to method='dense' "
                             "(the Nyström eigenproblem is m×m and always "
                             "uses eigh)")
        m = num_landmarks or default_num_landmarks(x.shape[0], k)
        y, evals = nystrom_spectral_embedding(
            lm_key, x, k, m, gamma=gamma, use_pallas=use_pallas)
    else:
        raise ValueError(f"unknown method: {method!r}")
    assign, _ = kmeans(km_key, y, k)
    return assign, y, evals
