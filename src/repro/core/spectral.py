"""Spectral clustering — Algorithm I of the paper, in JAX.

Steps (verbatim from the paper's pseudo-code):

  A       = affinity matrix (RBF over pairwise distances)
  D       = diag(sum_j A_ij)
  L       = D - A                      (unnormalized Laplacian)
  L_norm  = I - D^{-1/2} A D^{-1/2}    (normalized Laplacian)
  X       = first k eigenvectors of L_norm (smallest eigenvalues)
  Y       = row-normalized X
  cluster rows of Y with k-means; assign point i to cluster of row i.

The affinity computation is the O(n²d) hotspot; ``use_pallas=True`` routes
it through the TPU Pallas kernel (``kernels/affinity_pallas.py``), whose
jnp oracle is ``kernels/ref.py``.  Eigendecomposition stays in XLA's
``eigh`` (TPU-native).  Also exposes ``eigengap_k`` — the paper's
"first large gap" heuristic for choosing the number of clusters.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans, pairwise_sq_dists


def affinity_matrix(x, *, gamma: float | None = None, use_pallas: bool = False):
    """RBF affinity A_ij = exp(-gamma ||x_i - x_j||^2), zero diagonal."""
    if use_pallas:
        from repro.kernels import ops as kops
        d2 = kops.pairwise_sq_dists(x, x)
    else:
        d2 = pairwise_sq_dists(x, x)
    if gamma is None:
        # median heuristic: gamma = 1 / (2 * median(d2))
        med = jnp.median(jnp.where(d2 > 0, d2, jnp.nan))
        med = jnp.nan_to_num(med, nan=1.0)
        gamma = 1.0 / jnp.maximum(2.0 * med, 1e-12)
    a = jnp.exp(-gamma * d2)
    return a * (1.0 - jnp.eye(x.shape[0], dtype=a.dtype))


def normalized_laplacian(a):
    d = jnp.sum(a, axis=1)
    inv_sqrt = jax.lax.rsqrt(jnp.maximum(d, 1e-12))
    n = a.shape[0]
    return jnp.eye(n) - a * inv_sqrt[:, None] * inv_sqrt[None, :]


def spectral_embedding(a, k: int):
    """First-k eigenvectors of L_norm (ascending eigenvalues), row-normed."""
    lap = normalized_laplacian(a)
    evals, evecs = jnp.linalg.eigh(lap)        # ascending
    x = evecs[:, :k]
    norms = jnp.linalg.norm(x, axis=1, keepdims=True)
    y = x / jnp.maximum(norms, 1e-12)
    return y, evals


def eigengap_k(evals, max_k: int = 10) -> jnp.ndarray:
    """Paper §3.4: number of eigenvalues before the first large gap."""
    gaps = jnp.diff(evals[: max_k + 1])
    return jnp.argmax(gaps) + 1


@functools.partial(jax.jit, static_argnames=("k", "use_pallas"))
def spectral_cluster(key, x, k: int, *, gamma: float | None = None,
                     use_pallas: bool = False):
    """Full Algorithm I.  x: (n, d) points -> (assignments, Y, evals)."""
    a = affinity_matrix(x, gamma=gamma, use_pallas=use_pallas)
    y, evals = spectral_embedding(a, k)
    assign, _ = kmeans(key, y, k)
    return assign, y, evals
