"""Spectral clustering — Algorithm I of the paper, in JAX.

Steps (verbatim from the paper's pseudo-code):

  A       = affinity matrix (RBF over pairwise distances)
  D       = diag(sum_j A_ij)
  L       = D - A                      (unnormalized Laplacian)
  L_norm  = I - D^{-1/2} A D^{-1/2}    (normalized Laplacian)
  X       = first k eigenvectors of L_norm (smallest eigenvalues)
  Y       = row-normalized X
  cluster rows of Y with k-means; assign point i to cluster of row i.

The affinity computation is the O(n²d) hotspot; ``use_pallas=True`` routes
it through the TPU Pallas kernels (``kernels/affinity_pallas.py``), whose
jnp oracles are in ``kernels/ref.py``.

Two scale regimes:

* ``method="dense"`` — the exact path above.  ``solver="eigh"`` is XLA's
  full eigendecomposition (TPU-native, O(n³)); ``solver="subspace"``
  replaces it with orthogonal (subspace) iteration on 2I − L_norm, which
  only costs O(n²k) per sweep and recovers the same smallest-k invariant
  subspace when k ≪ n.
* ``method="nystrom"`` — the approximate path for cross-device-FL cohort
  sizes (N ~ 10⁵): sample m ≪ N landmarks, compute only the (N, m)
  cross-affinity C and the (m, m) landmark block W, and recover the
  normalized-Laplacian embedding from the one-shot Nyström extension
  (Fowlkes et al., 2004):  Â = D̂^{-1/2} C W⁺ Cᵀ D̂^{-1/2} with
  D̂ = diag(C W⁺ Cᵀ 1).  Everything is O(N·m) memory / O(N m d + m³)
  compute, so N = 100k clients fits where the dense O(N²) matrix cannot.

Also exposes ``eigengap_k`` — the paper's "first large gap" heuristic for
choosing the number of clusters.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans, pairwise_sq_dists

_EPS = 1e-12
# gamma estimation subsamples the distance matrix beyond this many rows —
# the median of a few thousand rows is statistically indistinguishable
# from the full median and avoids sorting 10¹⁰ entries at N = 100k.
_GAMMA_SAMPLE_ROWS = 4096


def auto_gamma(d2):
    """Median heuristic: gamma = 1 / (2 · median of positive distances).

    Uses ``nanmedian`` over the zero-masked matrix — ``jnp.median`` on a
    NaN-masked array returns NaN, which used to silently collapse the
    data-adaptive bandwidth to the 0.5 fallback for *every* input.
    """
    if d2.shape[0] > _GAMMA_SAMPLE_ROWS:
        d2 = d2[:_GAMMA_SAMPLE_ROWS]
    med = jnp.nanmedian(jnp.where(d2 > 0, d2, jnp.nan))
    med = jnp.nan_to_num(med, nan=1.0)
    return 1.0 / jnp.maximum(2.0 * med, _EPS)


def affinity_matrix(x, *, gamma: float | None = None, use_pallas: bool = False):
    """RBF affinity A_ij = exp(-gamma ||x_i - x_j||^2), zero diagonal."""
    if use_pallas:
        from repro.kernels import ops as kops
        d2 = kops.pairwise_sq_dists(x, x)
    else:
        d2 = pairwise_sq_dists(x, x)
    if gamma is None:
        # zero the diagonal first: self-distances are 0 by definition but
        # the matmul form leaves tiny positive junk that would leak past
        # auto_gamma's positive-entry mask and bias the median low.
        eye = jnp.eye(x.shape[0], dtype=d2.dtype)
        gamma = auto_gamma(d2 * (1.0 - eye))
    a = jnp.exp(-gamma * d2)
    return a * (1.0 - jnp.eye(x.shape[0], dtype=a.dtype))


def cross_affinity(x, z, *, gamma, use_pallas: bool = False):
    """Rectangular RBF affinity exp(-gamma ||x_i - z_j||²), (n, m)."""
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.rbf_cross_affinity(x, z, gamma)
    return jnp.exp(-gamma * pairwise_sq_dists(x, z))


def normalized_laplacian(a):
    d = jnp.sum(a, axis=1)
    inv_sqrt = jax.lax.rsqrt(jnp.maximum(d, _EPS))
    n = a.shape[0]
    return jnp.eye(n) - a * inv_sqrt[:, None] * inv_sqrt[None, :]


def _row_normalize(x):
    norms = jnp.linalg.norm(x, axis=1, keepdims=True)
    return x / jnp.maximum(norms, _EPS)


def spectral_embedding(a, k: int, *, solver: str = "eigh",
                       iters: int = 60):
    """First-k eigenvectors of L_norm (ascending eigenvalues), row-normed.

    ``solver="eigh"`` — exact, O(n³).  ``solver="subspace"`` — orthogonal
    iteration on 2I − L_norm (eigenvalues of L_norm lie in [0, 2], so its
    smallest-k subspace is the dominant subspace of the shift), O(n²k·iters),
    followed by a Rayleigh–Ritz rotation; returns only k eigenvalues.
    """
    if solver == "eigh":
        lap = normalized_laplacian(a)
        evals, evecs = jnp.linalg.eigh(lap)        # ascending
        x = evecs[:, :k]
    elif solver == "subspace":
        x, evals = _subspace_smallest_k(a, k, iters=iters)
    else:
        raise ValueError(f"unknown solver: {solver!r}")
    return _row_normalize(x), evals


def _subspace_smallest_k(a, k: int, *, iters: int = 60):
    """Smallest-k eigenpairs of L_norm = I − A_norm without full eigh.

    Orthogonal iteration: Q ← qr(B Q) with B = 2I − L_norm = I + A_norm
    (spd, dominant subspace = smallest-k of L_norm), then a k×k
    Rayleigh–Ritz solve to rotate Q onto the Ritz vectors and recover the
    eigenvalues of L_norm itself.
    """
    n = a.shape[0]
    d = jnp.sum(a, axis=1)
    inv_sqrt = jax.lax.rsqrt(jnp.maximum(d, _EPS))
    a_norm = a * inv_sqrt[:, None] * inv_sqrt[None, :]

    q0 = jax.random.normal(jax.random.PRNGKey(0), (n, k), a.dtype)
    q0, _ = jnp.linalg.qr(q0)

    def body(_, q):
        q, _ = jnp.linalg.qr(q + a_norm @ q)       # B q = q + A_norm q
        return q

    q = jax.lax.fori_loop(0, iters, body, q0)
    # Rayleigh-Ritz on L_norm: T = Qᵀ L Q = Qᵀ Q − Qᵀ A_norm Q
    t = q.T @ (q - a_norm @ q)
    t = 0.5 * (t + t.T)
    evals, u = jnp.linalg.eigh(t)                  # ascending
    return q @ u, evals


def nystrom_spectral_embedding(key, x, k: int, num_landmarks: int, *,
                               gamma: float | None = None,
                               use_pallas: bool = False):
    """Approximate normalized-Laplacian embedding via Nyström landmarks.

    Samples m landmarks Z ⊂ x, computes only the (n, m) cross-affinity
    C = exp(-γ d²(x, Z)) and its landmark block W = C[Z], and extends the
    m×m eigenproblem to all n points:

        D̂ = diag(C W⁺ Cᵀ 1)                approximate degrees
        S  = D̂^{-1/2} C                     degree-normalized cross block
        M  = W^{-1/2} (Sᵀ S) W^{-1/2}       (m, m), symmetric
        Â  = S W⁺ Sᵀ  has eigenvectors  V = S W^{-1/2} U Λ^{-1/2}

    The top-k eigenpairs of Â are the smallest-k of L_norm = I − Â.
    Returns (Y row-normalized (n, k), evals of L_norm ascending (m,)).
    """
    n = x.shape[0]
    m = min(int(num_landmarks), n)
    if m < k:
        raise ValueError(f"num_landmarks={m} must be >= k={k}")
    x = x.astype(jnp.float32)
    idx = jax.random.choice(key, n, (m,), replace=False)
    z = x[idx]
    if gamma is None:
        rows = x[:min(n, _GAMMA_SAMPLE_ROWS)]
        gamma = auto_gamma(pairwise_sq_dists(rows, z))
    c = cross_affinity(x, z, gamma=gamma, use_pallas=use_pallas)   # (n, m)
    w = c[idx]                                                     # (m, m)
    w = 0.5 * (w + w.T)

    ew, uw = jnp.linalg.eigh(w)
    # pseudo-inverse powers with eigenvalue clipping: RBF kernel blocks are
    # PSD in exact arithmetic but near-singular when landmarks cluster.
    good = ew > 1e-6 * jnp.max(ew)
    inv = jnp.where(good, 1.0 / jnp.maximum(ew, _EPS), 0.0)
    inv_sqrt_w = uw * jnp.sqrt(inv)[None, :]        # W^{-1/2} = U Λ^{-1/2}
    w_isqrt = inv_sqrt_w @ uw.T                     # (m, m)

    # approximate degrees: d̂ = C W⁺ (Cᵀ 1)
    col = c.T @ jnp.ones((n,), c.dtype)             # (m,)
    d_hat = c @ (w_isqrt @ (w_isqrt @ col))
    inv_sqrt_d = jax.lax.rsqrt(jnp.maximum(d_hat, _EPS))
    s = c * inv_sqrt_d[:, None]                     # (n, m)

    mm = w_isqrt @ (s.T @ s) @ w_isqrt
    mm = 0.5 * (mm + mm.T)
    em, um = jnp.linalg.eigh(mm)                    # ascending
    top = um[:, ::-1][:, :k]                        # largest-k of Â
    lam = em[::-1][:k]
    v = (s @ (w_isqrt @ top)) * jax.lax.rsqrt(
        jnp.maximum(lam, _EPS))[None, :]            # (n, k), ≈ orthonormal
    evals = 1.0 - em[::-1]                          # L_norm spectrum, asc.
    return _row_normalize(v), evals


def default_num_landmarks(n: int, k: int) -> int:
    return min(n, max(8 * k, 64))


def eigengap_k(evals, max_k: int = 10) -> jnp.ndarray:
    """Paper §3.4: number of eigenvalues before the first large gap."""
    gaps = jnp.diff(evals[: max_k + 1])
    return jnp.argmax(gaps) + 1


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "method",
                                             "num_landmarks", "solver"))
def spectral_cluster(key, x, k: int, *, gamma: float | None = None,
                     use_pallas: bool = False, method: str = "dense",
                     num_landmarks: int | None = None,
                     solver: str = "eigh"):
    """Full Algorithm I.  x: (n, d) points -> (assignments, Y, evals).

    ``method="dense"`` computes the exact n×n affinity (``solver`` picks
    the eigensolver); ``method="nystrom"`` uses ``num_landmarks`` sampled
    landmarks (default min(n, max(8k, 64))) and scales to n ~ 10⁵.
    """
    km_key, lm_key = jax.random.split(key)
    if method == "dense":
        if num_landmarks is not None:
            raise ValueError("num_landmarks only applies to method='nystrom'")
        a = affinity_matrix(x, gamma=gamma, use_pallas=use_pallas)
        y, evals = spectral_embedding(a, k, solver=solver)
    elif method == "nystrom":
        if solver != "eigh":
            raise ValueError("solver only applies to method='dense' "
                             "(the Nyström eigenproblem is m×m and always "
                             "uses eigh)")
        m = num_landmarks or default_num_landmarks(x.shape[0], k)
        y, evals = nystrom_spectral_embedding(
            lm_key, x, k, m, gamma=gamma, use_pallas=use_pallas)
    else:
        raise ValueError(f"unknown method: {method!r}")
    assign, _ = kmeans(km_key, y, k)
    return assign, y, evals
