"""K-means(++) in JAX — the final stage of Algorithm I (spectral clustering).

Fixed-iteration ``lax.fori_loop`` so it jits cleanly; k-means++ seeding via
``jax.random.choice`` over squared-distance weights.  Distances route
through the same pairwise-distance op the Pallas affinity kernel
implements (``kernels/ops.pairwise_sq_dists`` when enabled).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def pairwise_sq_dists(x, y):
    """(n, d), (m, d) -> (n, m) squared euclidean distances."""
    xn = jnp.sum(x * x, axis=-1)[:, None]
    yn = jnp.sum(y * y, axis=-1)[None, :]
    return jnp.maximum(xn + yn - 2.0 * (x @ y.T), 0.0)


def kmeans_plus_plus_init(key, x, k: int):
    n = x.shape[0]
    first = jax.random.randint(key, (), 0, n)
    centers0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(i, carry):
        centers, key = carry
        key, sub = jax.random.split(key)
        d = pairwise_sq_dists(x, centers)                  # (n, k)
        mask = jnp.arange(k)[None, :] < i
        dmin = jnp.min(jnp.where(mask, d, jnp.inf), axis=1)
        probs = dmin / jnp.maximum(jnp.sum(dmin), 1e-12)
        idx = jax.random.choice(sub, n, p=probs)
        return centers.at[i].set(x[idx]), key

    centers, _ = jax.lax.fori_loop(1, k, body, (centers0, key))
    return centers


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key, x, k: int, iters: int = 25):
    """Lloyd iterations.  Returns (assignments (n,), centers (k, d))."""
    centers = kmeans_plus_plus_init(key, x, k)

    def body(_, centers):
        d = pairwise_sq_dists(x, centers)
        assign = jnp.argmin(d, axis=1)                     # (n,)
        onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)  # (n, k)
        counts = jnp.sum(onehot, axis=0)                   # (k,)
        sums = onehot.T @ x                                # (k, d)
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # keep old center when a cluster empties
        return jnp.where(counts[:, None] > 0, new, centers)

    centers = jax.lax.fori_loop(0, iters, body, centers)
    assign = jnp.argmin(pairwise_sq_dists(x, centers), axis=1)
    return assign, centers
