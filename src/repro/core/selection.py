"""Client-selection policies: FedAvg(random), K-Center, FAVOR, DQRE-SCnet.

The paper's baselines (Table 2) and its contribution, behind one
interface.  A policy sees a ``RoundState`` (client weight-delta embeddings
+ global-model embedding) and returns the cohort for the next
communication round; learning policies also consume a reward after the
round (FAVOR-style  r = Ξ^(acc − target) − 1,  Ξ = 64).

DQRE-SCnet (the paper, Algorithm II): spectrally cluster the client
embeddings (Algorithm I), then a Deep-Q agent (current + target nets)
chooses *clusters*; clients are drawn without replacement from the chosen
clusters ("rewarded users"), de-biasing the cohort under non-IID skew.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax

from repro.core.dqn import DQNAgent, DQNConfig
from repro.core.kmeans import pairwise_sq_dists
from repro.core.spectral import spectral_cluster


@dataclasses.dataclass
class RoundState:
    round_idx: int
    client_embeds: np.ndarray          # (N, dim)
    global_embed: np.ndarray           # (dim,)
    prev_accuracy: float


@dataclasses.dataclass
class Feedback:
    accuracy: float
    reward: float
    selected: np.ndarray


class SelectionPolicy:
    name = "base"

    def __init__(self, num_clients: int, clients_per_round: int,
                 embed_dim: int, seed: int = 0):
        self.num_clients = num_clients
        self.clients_per_round = clients_per_round
        self.embed_dim = embed_dim
        self.rng = np.random.default_rng(seed)

    def select(self, state: RoundState) -> np.ndarray:
        raise NotImplementedError

    def update(self, state: RoundState, next_state: RoundState,
               feedback: Feedback) -> None:
        pass


class RandomSelection(SelectionPolicy):
    """FedAvg: uniform random cohort (McMahan et al.)."""
    name = "fedavg"

    def select(self, state: RoundState) -> np.ndarray:
        return self.rng.choice(self.num_clients, self.clients_per_round,
                               replace=False)


class KCenterSelection(SelectionPolicy):
    """Greedy k-center (farthest-point) over client embeddings."""
    name = "kcenter"

    def select(self, state: RoundState) -> np.ndarray:
        x = state.client_embeds
        n, k = self.num_clients, self.clients_per_round
        chosen = [int(self.rng.integers(n))]
        d2 = np.asarray(pairwise_sq_dists(x, x[chosen]))[:, 0]
        while len(chosen) < k:
            nxt = int(np.argmax(d2))
            chosen.append(nxt)
            d2 = np.minimum(
                d2, np.asarray(pairwise_sq_dists(x, x[nxt:nxt + 1]))[:, 0])
        return np.asarray(chosen)


class FavorSelection(SelectionPolicy):
    """FAVOR (Wang et al. 2020): per-client DQN, no clustering.

    State = [global embed ‖ all client embeds]; the Q head scores each
    client; the cohort is the top-K by Q with ε-greedy exploration.
    """
    name = "favor"

    def __init__(self, num_clients, clients_per_round, embed_dim, seed=0,
                 dqn_overrides: Optional[dict] = None):
        super().__init__(num_clients, clients_per_round, embed_dim, seed)
        cfg = DQNConfig(state_dim=(num_clients + 1) * embed_dim,
                        num_actions=num_clients,
                        **(dqn_overrides or {}))
        self.agent = DQNAgent(jax.random.PRNGKey(seed), cfg)

    def _state_vec(self, state: RoundState) -> np.ndarray:
        return np.concatenate([state.global_embed.ravel(),
                               state.client_embeds.ravel()]).astype(np.float32)

    def select(self, state: RoundState) -> np.ndarray:
        s = self._state_vec(state)
        self.agent.steps += 1
        q = self.agent.q_values(s)
        k = self.clients_per_round
        eps = self.agent.epsilon()
        n_rand = int(round(eps * k))
        top = np.argsort(-q)
        picked = list(top[: k - n_rand])
        if n_rand:
            rest = np.setdiff1d(np.arange(self.num_clients), picked)
            picked += list(self.rng.choice(rest, n_rand, replace=False))
        return np.asarray(picked[:k])

    def update(self, state, next_state, feedback):
        s, s2 = self._state_vec(state), self._state_vec(next_state)
        for a in feedback.selected:
            self.agent.observe(s, int(a), feedback.reward, s2)
        self.agent.train_step(self.rng)


class DQREScSelection(SelectionPolicy):
    """DQRE-SCnet (the paper): spectral clustering + cluster-level DQN."""
    name = "dqre_sc"

    def __init__(self, num_clients, clients_per_round, embed_dim, seed=0,
                 num_clusters: int = 8, use_pallas: bool = False,
                 auto_k: bool = False, approx_method: str = "dense",
                 num_landmarks: Optional[int] = None,
                 dqn_overrides: Optional[dict] = None):
        super().__init__(num_clients, clients_per_round, embed_dim, seed)
        self.num_clusters = num_clusters
        self.use_pallas = use_pallas
        # paper §3.4: pick k by the first large eigengap of L_norm, capped
        # by num_clusters (the DQN action space stays fixed; clusters
        # beyond k_hat are simply empty that round).
        self.auto_k = auto_k
        # Algorithm I scale regime: "dense" is the exact O(N²)/O(N³) path,
        # "nystrom" the landmark approximation viable at N ~ 10⁵ clients.
        self.approx_method = approx_method
        self.num_landmarks = num_landmarks
        cfg = DQNConfig(state_dim=(num_clusters + 1) * embed_dim,
                        num_actions=num_clusters,
                        **(dqn_overrides or {}))
        self.agent = DQNAgent(jax.random.PRNGKey(seed), cfg)
        self._key = jax.random.PRNGKey(seed + 1)
        self._last_assign: Optional[np.ndarray] = None
        self._last_state_vec: Optional[np.ndarray] = None
        self._last_actions: Optional[list] = None
        # select() and update() see the same embeddings once per round —
        # cache the assignment by content fingerprint so Algorithm I runs
        # once, not twice, per round.
        self._assign_cache: Optional[tuple] = None   # (fingerprint, assign)
        self.cluster_computes = 0

    # -- Algorithm I: cluster the client embeddings -------------------------
    @staticmethod
    def _fingerprint(embeds: np.ndarray) -> bytes:
        import hashlib
        h = hashlib.sha1(np.ascontiguousarray(embeds).tobytes())
        h.update(str(embeds.shape).encode())
        return h.digest()

    def _cluster(self, embeds: np.ndarray):
        embeds = np.asarray(embeds, np.float32)
        fp = self._fingerprint(embeds)
        if self._assign_cache is not None and self._assign_cache[0] == fp:
            return self._assign_cache[1]
        self._key, sub = jax.random.split(self._key)
        k = self.num_clusters
        if self.auto_k:
            from repro.core.spectral import (affinity_matrix,
                                             default_num_landmarks,
                                             eigengap_k,
                                             nystrom_spectral_embedding,
                                             spectral_embedding)
            import jax.numpy as jnp
            xe = jnp.asarray(embeds)
            if self.approx_method == "nystrom":
                # the approximate L_norm spectrum is enough for the
                # eigengap — never build the dense n×n affinity here, or
                # auto_k would reintroduce the O(N²)/O(N³) ceiling the
                # landmark path exists to remove.
                self._key, lm = jax.random.split(self._key)
                m = self.num_landmarks or default_num_landmarks(
                    len(embeds), self.num_clusters)
                _, evals = nystrom_spectral_embedding(
                    lm, xe, self.num_clusters, m,
                    use_pallas=self.use_pallas)
            else:
                a = affinity_matrix(xe, use_pallas=self.use_pallas)
                _, evals = spectral_embedding(a, self.num_clusters)
            k = int(np.clip(int(eigengap_k(evals, self.num_clusters)),
                            2, self.num_clusters))
        assign, _, _ = spectral_cluster(
            sub, embeds, k, use_pallas=self.use_pallas,
            method=self.approx_method, num_landmarks=self.num_landmarks)
        assign = np.asarray(assign)
        self.cluster_computes += 1
        self._assign_cache = (fp, assign)
        return assign

    def _state_vec(self, state: RoundState, assign: np.ndarray) -> np.ndarray:
        cents = np.zeros((self.num_clusters, self.embed_dim), np.float32)
        for c in range(self.num_clusters):
            m = assign == c
            if m.any():
                cents[c] = state.client_embeds[m].mean(axis=0)
        return np.concatenate([state.global_embed.ravel(),
                               cents.ravel()]).astype(np.float32)

    # -- Algorithm II: DQN chooses clusters, clients drawn from them --------
    def select(self, state: RoundState) -> np.ndarray:
        assign = self._cluster(state.client_embeds)
        s = self._state_vec(state, assign)
        self._last_assign, self._last_state_vec = assign, s
        self.agent.steps += 1
        q = self.agent.q_values(s)
        eps = self.agent.epsilon()

        pools = {c: list(np.flatnonzero(assign == c))
                 for c in range(self.num_clusters)}
        for pool in pools.values():
            self.rng.shuffle(pool)
        picked, actions = [], []
        order = np.argsort(-q)
        while len(picked) < self.clients_per_round:
            if self.rng.random() < eps:
                c = int(self.rng.integers(self.num_clusters))
            else:
                c = int(next((c for c in order if pools[c]), order[0]))
            if not pools[c]:
                nonempty = [cc for cc in range(self.num_clusters) if pools[cc]]
                if not nonempty:
                    break
                c = int(self.rng.choice(nonempty))
            picked.append(pools[c].pop())
            actions.append(c)
        self._last_actions = actions
        return np.asarray(picked)

    def update(self, state, next_state, feedback):
        assign2 = self._cluster(next_state.client_embeds)
        s2 = self._state_vec(next_state, assign2)
        for a in (self._last_actions or []):
            self.agent.observe(self._last_state_vec, int(a),
                               feedback.reward, s2)
        self.agent.train_step(self.rng)


POLICIES = {
    "fedavg": RandomSelection,
    "kcenter": KCenterSelection,
    "favor": FavorSelection,
    "dqre_sc": DQREScSelection,
}


def make_policy(name: str, num_clients: int, clients_per_round: int,
                embed_dim: int, seed: int = 0, **kw) -> SelectionPolicy:
    return POLICIES[name](num_clients, clients_per_round, embed_dim,
                          seed=seed, **kw)


def favor_reward(accuracy: float, target: float, xi: float = 64.0) -> float:
    """FAVOR's reward shaping — also used by DQRE-SC (paper §3.3)."""
    return float(xi ** (accuracy - target) - 1.0)
