"""Client-selection policies: FedAvg(random), K-Center, FAVOR, DQRE-SCnet.

The paper's baselines (Table 2) and its contribution, behind one
interface.  A policy sees a ``RoundState`` (client weight-delta embeddings
+ global-model embedding) and returns the cohort for the next
communication round; learning policies also consume a reward after the
round (FAVOR-style  r = Ξ^(acc − target) − 1,  Ξ = 64).

DQRE-SCnet (the paper, Algorithm II): spectrally cluster the client
embeddings (Algorithm I), then a Deep-Q agent (current + target nets)
chooses *clusters*; clients are drawn without replacement from the chosen
clusters ("rewarded users"), de-biasing the cohort under non-IID skew.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax

from repro.core.dqn import DQNAgent, DQNConfig
from repro.core.kmeans import pairwise_sq_dists


@dataclasses.dataclass
class RoundState:
    round_idx: int
    client_embeds: np.ndarray          # (N, dim)
    global_embed: np.ndarray           # (dim,)
    prev_accuracy: float


@dataclasses.dataclass
class Feedback:
    accuracy: float
    reward: float
    selected: np.ndarray


class SelectionPolicy:
    name = "base"

    def __init__(self, num_clients: int, clients_per_round: int,
                 embed_dim: int, seed: int = 0):
        self.num_clients = num_clients
        self.clients_per_round = clients_per_round
        self.embed_dim = embed_dim
        self.rng = np.random.default_rng(seed)

    def select(self, state: RoundState) -> np.ndarray:
        raise NotImplementedError

    def update(self, state: RoundState, next_state: RoundState,
               feedback: Feedback) -> None:
        pass


class RandomSelection(SelectionPolicy):
    """FedAvg: uniform random cohort (McMahan et al.)."""
    name = "fedavg"

    def select(self, state: RoundState) -> np.ndarray:
        return self.rng.choice(self.num_clients, self.clients_per_round,
                               replace=False)


class KCenterSelection(SelectionPolicy):
    """Greedy k-center (farthest-point) over client embeddings."""
    name = "kcenter"

    def select(self, state: RoundState) -> np.ndarray:
        x = state.client_embeds
        n, k = self.num_clients, self.clients_per_round
        chosen = [int(self.rng.integers(n))]
        d2 = np.asarray(pairwise_sq_dists(x, x[chosen]))[:, 0]
        while len(chosen) < k:
            nxt = int(np.argmax(d2))
            chosen.append(nxt)
            d2 = np.minimum(
                d2, np.asarray(pairwise_sq_dists(x, x[nxt:nxt + 1]))[:, 0])
        return np.asarray(chosen)


class FavorSelection(SelectionPolicy):
    """FAVOR (Wang et al. 2020): per-client DQN, no clustering.

    State = [global embed ‖ all client embeds]; the Q head scores each
    client; the cohort is the top-K by Q with ε-greedy exploration.
    """
    name = "favor"

    def __init__(self, num_clients, clients_per_round, embed_dim, seed=0,
                 dqn_overrides: Optional[dict] = None):
        super().__init__(num_clients, clients_per_round, embed_dim, seed)
        cfg = DQNConfig(state_dim=(num_clients + 1) * embed_dim,
                        num_actions=num_clients,
                        **(dqn_overrides or {}))
        self.agent = DQNAgent(jax.random.PRNGKey(seed), cfg)

    def _state_vec(self, state: RoundState) -> np.ndarray:
        return np.concatenate([state.global_embed.ravel(),
                               state.client_embeds.ravel()]).astype(np.float32)

    def select(self, state: RoundState) -> np.ndarray:
        s = self._state_vec(state)
        self.agent.steps += 1
        q = self.agent.q_values(s)
        k = self.clients_per_round
        eps = self.agent.epsilon()
        n_rand = int(round(eps * k))
        top = np.argsort(-q)
        picked = list(top[: k - n_rand])
        if n_rand:
            rest = np.setdiff1d(np.arange(self.num_clients), picked)
            picked += list(self.rng.choice(rest, n_rand, replace=False))
        return np.asarray(picked[:k])

    def update(self, state, next_state, feedback):
        s, s2 = self._state_vec(state), self._state_vec(next_state)
        for a in feedback.selected:
            self.agent.observe(s, int(a), feedback.reward, s2)
        self.agent.train_step(self.rng)


def _make_cohort_config(num_clusters, approx_method, num_landmarks,
                        landmarks, use_pallas, auto_k, warm_start):
    """Engine config shared by the cluster-based policies (stratified +
    dqre_sc).  approx_method maps 1:1 onto engine methods ("dense",
    "nystrom", "sharded", "auto"); "dense" stays the default so small
    simulated cohorts keep the exact Algorithm I path."""
    from repro.cohort import CohortConfig
    return CohortConfig(num_clusters=num_clusters, method=approx_method,
                        num_landmarks=num_landmarks, landmarks=landmarks,
                        use_pallas=use_pallas, auto_k=auto_k,
                        warm_start=warm_start)


class StratifiedSelection(SelectionPolicy):
    """Cluster-stratified uniform draw: Algorithm I without Algorithm II.

    Clusters the client embeddings through the same
    :class:`repro.cohort.CohortEngine` as DQRE-SCnet, then draws the
    cohort round-robin across clusters (pools shuffled, popped without
    replacement) — the serving path's ``policy="stratified"`` baseline,
    here for the simulation so the realism benchmarks can isolate what
    the *learned* cluster choice adds under system heterogeneity.
    """
    name = "stratified"

    def __init__(self, num_clients, clients_per_round, embed_dim, seed=0,
                 num_clusters: int = 8, use_pallas: bool = False,
                 auto_k: bool = False, approx_method: str = "dense",
                 num_landmarks: Optional[int] = None,
                 landmarks: str = "uniform", warm_start: bool = True):
        super().__init__(num_clients, clients_per_round, embed_dim, seed)
        from repro.cohort import CohortEngine
        self.num_clusters = num_clusters
        self.engine = CohortEngine(
            _make_cohort_config(num_clusters, approx_method, num_landmarks,
                                landmarks, use_pallas, auto_k, warm_start),
            seed=seed + 1)

    def select(self, state: RoundState) -> np.ndarray:
        assign = self.engine.select(state.client_embeds).assign
        pools = [list(np.flatnonzero(assign == c))
                 for c in range(self.num_clusters)]
        for pool in pools:
            self.rng.shuffle(pool)
        picked: list = []
        while len(picked) < self.clients_per_round and any(pools):
            for pool in pools:
                if pool and len(picked) < self.clients_per_round:
                    picked.append(pool.pop())
        return np.asarray(picked)


class DQREScSelection(SelectionPolicy):
    """DQRE-SCnet (the paper): spectral clustering + cluster-level DQN.

    Algorithm I (clustering) is delegated wholesale to the cohort
    subsystem: a :class:`repro.cohort.CohortEngine` owns method
    resolution (dense / Nyström / mesh-sharded Nyström), landmark
    strategy, the per-round fingerprint cache, and drift-gated
    warm-started re-clustering.  Algorithm II (the cluster-level DQN
    and the ε-greedy cohort draw) is delegated to
    :class:`repro.policy.ClusterPolicy` — the same component the
    serving path (``launch/serve.CohortServer``) runs online — fed here
    with the simulation state [global embed ‖ cluster centroids].
    """
    name = "dqre_sc"

    def __init__(self, num_clients, clients_per_round, embed_dim, seed=0,
                 num_clusters: int = 8, use_pallas: bool = False,
                 auto_k: bool = False, approx_method: str = "dense",
                 num_landmarks: Optional[int] = None,
                 landmarks: str = "uniform", warm_start: bool = True,
                 cohort_config=None,
                 dqn_overrides: Optional[dict] = None):
        super().__init__(num_clients, clients_per_round, embed_dim, seed)
        from repro.cohort import CohortEngine
        self.num_clusters = num_clusters
        if cohort_config is None:
            cohort_config = _make_cohort_config(
                num_clusters, approx_method, num_landmarks, landmarks,
                use_pallas, auto_k, warm_start)
        else:
            if cohort_config.num_clusters != num_clusters:
                # the DQN action space, the pool loop in select(), and
                # the engine's assignment range must agree — a mismatch
                # would silently make clusters >= num_clusters
                # unselectable
                raise ValueError(
                    f"cohort_config.num_clusters="
                    f"{cohort_config.num_clusters} must equal the "
                    f"policy's num_clusters={num_clusters}")
            overlapping = dict(approx_method=(approx_method, "dense"),
                               num_landmarks=(num_landmarks, None),
                               landmarks=(landmarks, "uniform"),
                               use_pallas=(use_pallas, False),
                               auto_k=(auto_k, False),
                               warm_start=(warm_start, True))
            clash = [name for name, (got, default) in overlapping.items()
                     if got != default]
            if clash:
                raise ValueError(
                    f"pass {clash} inside cohort_config, not alongside "
                    f"it — an explicit cohort_config replaces those "
                    f"constructor arguments entirely")
        self.engine = CohortEngine(cohort_config, seed=seed + 1)
        from repro.policy import ClusterPolicy
        self.cluster_policy = ClusterPolicy(
            num_clusters, state_dim=(num_clusters + 1) * embed_dim,
            seed=seed, dqn_overrides=dqn_overrides)
        self.agent = self.cluster_policy.agent   # back-compat alias
        self._last_assign: Optional[np.ndarray] = None
        self._last_state_vec: Optional[np.ndarray] = None
        self._last_actions: Optional[list] = None

    @property
    def cluster_computes(self) -> int:
        """Algorithm I solves actually executed (engine cache hits excluded)."""
        return self.engine.stats["solves"]

    # -- Algorithm I: cluster the client embeddings -------------------------
    def _cluster(self, embeds: np.ndarray):
        return self.engine.select(embeds).assign

    def _state_vec(self, state: RoundState, assign: np.ndarray) -> np.ndarray:
        cents = np.zeros((self.num_clusters, self.embed_dim), np.float32)
        for c in range(self.num_clusters):
            m = assign == c
            if m.any():
                cents[c] = state.client_embeds[m].mean(axis=0)
        return np.concatenate([state.global_embed.ravel(),
                               cents.ravel()]).astype(np.float32)

    # -- Algorithm II: DQN chooses clusters, clients drawn from them --------
    def select(self, state: RoundState) -> np.ndarray:
        assign = self._cluster(state.client_embeds)
        s = self._state_vec(state, assign)
        self._last_assign, self._last_state_vec = assign, s
        pools = {c: list(np.flatnonzero(assign == c))
                 for c in range(self.num_clusters)}
        picked, actions = self.cluster_policy.draw(
            self.rng, s, pools, self.clients_per_round)
        self._last_actions = actions
        return np.asarray(picked)

    def update(self, state, next_state, feedback):
        assign2 = self._cluster(next_state.client_embeds)
        s2 = self._state_vec(next_state, assign2)
        self.cluster_policy.observe(self._last_state_vec,
                                    self._last_actions or [],
                                    feedback.reward, s2)
        self.cluster_policy.train(self.rng)


POLICIES = {
    "fedavg": RandomSelection,
    "kcenter": KCenterSelection,
    "favor": FavorSelection,
    "stratified": StratifiedSelection,
    "dqre_sc": DQREScSelection,
}


def make_policy(name: str, num_clients: int, clients_per_round: int,
                embed_dim: int, seed: int = 0, **kw) -> SelectionPolicy:
    return POLICIES[name](num_clients, clients_per_round, embed_dim,
                          seed=seed, **kw)


def favor_reward(accuracy: float, target: float, xi: float = 64.0) -> float:
    """FAVOR's reward shaping — also used by DQRE-SC (paper §3.3)."""
    return float(xi ** (accuracy - target) - 1.0)
