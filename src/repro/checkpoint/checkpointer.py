"""Pytree checkpointing to .npz (orbax-free, offline-friendly).

Flattens a pytree to path-keyed arrays; restores with exact tree
structure and dtypes.  ``Checkpointer`` adds step management, retention,
and atomic writes (tmp + rename) so an interrupted save never corrupts
the latest checkpoint.
"""

from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np


_SEP = "::"


def _flatten_with_paths(tree):
    flat = {}

    def walk(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(node[k], path + [str(k)])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + [f"#{i}"])
        elif node is None:
            flat[_SEP.join(path) + "::__none__"] = np.zeros((0,))
        else:
            flat[_SEP.join(path)] = np.asarray(node)

    walk(tree, [])
    return flat


def _unflatten_from_paths(flat: dict, template=None):
    root: Any = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        is_none = parts[-1] == "__none__"
        if is_none:
            parts = parts[:-1]
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = None if is_none else val

    def fix(node):
        if isinstance(node, dict):
            keys = list(node)
            if keys and all(re.fullmatch(r"#\d+", k) for k in keys):
                return [fix(node[f"#{i}"]) for i in range(len(keys))]
            return {k: fix(v) for k, v in node.items()}
        return node

    tree = fix(root)
    if template is not None:
        # restore tuples/list distinction + leaf placement from template
        leaves, treedef = jax.tree.flatten(template)
        new_leaves = jax.tree.leaves(tree)
        if len(leaves) != len(new_leaves):
            raise ValueError("checkpoint does not match template structure")
        return jax.tree.unflatten(treedef, new_leaves)
    return tree


def save_pytree(path: str, tree) -> None:
    flat = _flatten_with_paths(jax.tree.map(np.asarray, tree))
    dirn = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(dirn, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=dirn, suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str, template=None):
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten_from_paths(flat, template)


class Checkpointer:
    """Step-indexed checkpoint directory with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.npz")

    def save(self, step: int, tree, metadata: Optional[dict] = None) -> str:
        path = self._path(step)
        save_pytree(path, tree)
        if metadata is not None:
            with open(path + ".json", "w") as f:
                json.dump(metadata, f)
        self._gc()
        return path

    def steps(self):
        out = []
        for f in os.listdir(self.directory):
            m = re.fullmatch(r"ckpt_(\d+)\.npz", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template=None, step: Optional[int] = None):
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        tree = load_pytree(self._path(step), template)
        meta_path = self._path(step) + ".json"
        metadata = None
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                metadata = json.load(f)
        return tree, step, metadata

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            for suffix in ("", ".json"):
                p = self._path(s) + suffix
                if os.path.exists(p):
                    os.unlink(p)
