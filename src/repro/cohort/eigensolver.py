"""Blocked, warm-startable top-k eigensolver for the landmark problems.

The cohort engine's two m×m eigenproblems (the landmark block W and the
normalized Nyström operator M) use dense ``eigh`` today, which is O(m³)
and single-device.  For m ≥ 10⁴ that is the bottleneck, so this module
provides blocked subspace (orthogonal) iteration:

* the W·Q matmul is evaluated in row panels (``block_rows``) so peak
  VMEM/L2 residency is O(block_rows · m) instead of O(m²) traffic in
  one burst — the part that actually scales with m²;
* orthogonalization is tall-skinny Householder QR on the (m, r) panel,
  O(m·r²).  (CholeskyQR2 would be the mesh-distributable alternative,
  but squaring the condition number is fatal in f32 for RBF landmark
  blocks, whose spectra decay to ~1e-8·λ_max — Householder it is.)
* iteration warm-starts from a caller-provided basis ``q0`` — the
  engine persists the previous round's converged basis in its
  ``CohortState`` and re-enters with a handful of refinement sweeps
  when client embeddings have drifted only slightly.

All inputs are assumed symmetric PSD (both W and M are), so the
dominant subspace of the operator itself is the wanted top-k.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

_EPS = 1e-12


def _blocked_matmul(w, q, block_rows: int, use_pallas: bool = False):
    """(m, m) @ (m, r) evaluated in row panels of w.

    ``use_pallas=True`` runs the panel loop inside one fused Pallas
    kernel (``kernels/nystrom_pallas.panel_matmul_pallas``) instead of
    round-tripping each panel through a separate XLA dispatch; the per-
    panel dots are identical, so the two routes agree bitwise.
    """
    m = w.shape[0]
    if block_rows >= m:
        return w @ q
    if use_pallas:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.panel_matmul(w, q, block_rows=block_rows)
    pad = (-m) % block_rows
    wp = jnp.pad(w, ((0, pad), (0, 0)))
    panels = wp.reshape(-1, block_rows, m)
    out = jax.lax.map(lambda panel: panel @ q, panels)
    return out.reshape(-1, q.shape[1])[:m]


def _panel_qr(v):
    """Orthonormal basis of the (m, r) panel's range (Householder QR)."""
    q, _ = jnp.linalg.qr(v)
    return q


@functools.partial(jax.jit, static_argnames=("r", "iters", "block_rows",
                                             "use_pallas"))
def subspace_topk(w, r: int, *, iters: int = 30, q0=None, key=None,
                  block_rows: int = 2048, use_pallas: bool = False):
    """Top-r eigenpairs of symmetric PSD ``w`` via blocked subspace iteration.

    Returns ``(evals, evecs)`` with eigenvalues in DESCENDING order,
    ``evecs`` (m, r) orthonormal Ritz vectors.  ``q0`` warm-starts the
    iteration (shape (m, r)); otherwise a seeded random range is used.
    """
    m = w.shape[0]
    if q0 is None:
        if key is None:
            # no caller key: fall back to a fixed, reproducible range
            # start — the converged Ritz basis is start-agnostic, the
            # constant stream is the point, not a bug
            # repro-lint: ignore[prng-constant-key]
            key = jax.random.PRNGKey(0)
        q0 = jax.random.normal(key, (m, r), w.dtype)
    q = _panel_qr(q0.astype(w.dtype))

    def body(_, q):
        return _panel_qr(_blocked_matmul(w, q, block_rows, use_pallas))

    q = jax.lax.fori_loop(0, iters, body, q)
    # Rayleigh-Ritz rotation onto the eigenbasis of the restriction
    t = q.T @ _blocked_matmul(w, q, block_rows, use_pallas)
    t = 0.5 * (t + t.T)
    evals, u = jnp.linalg.eigh(t)                 # ascending
    order = jnp.arange(r)[::-1]
    return evals[order], (q @ u)[:, order]


def topk_eigh(w, r: int, *, solver: str = "eigh", iters: int = 30,
              q0=None, key=None, block_rows: int = 2048,
              use_pallas: bool = False):
    """Top-r eigenpairs of symmetric PSD ``w``, descending eigenvalues.

    ``solver="eigh"`` — exact dense path (use for m ≲ 2048).
    ``solver="subspace"`` — blocked subspace iteration (see module doc);
    the only path viable at m ≥ 10⁴ and the only one that warm-starts.
    ``use_pallas`` routes the subspace row-panel matmuls through the
    fused Pallas kernel (no effect on the dense path).
    """
    if solver == "eigh":
        ew, uw = jnp.linalg.eigh(w)               # ascending
        return ew[::-1][:r], uw[:, ::-1][:, :r]
    if solver == "subspace":
        return subspace_topk(w, r, iters=iters, q0=q0, key=key,
                             block_rows=block_rows, use_pallas=use_pallas)
    raise ValueError(f"unknown solver {solver!r}")


def isqrt_from_eigs(evals, evecs):
    """Pseudo-inverse square root U Λ^{-1/2} Uᵀ with eigenvalue clipping.

    RBF kernel blocks are PSD in exact arithmetic but near-singular when
    landmarks cluster; eigenvalues below 1e-6·λ_max are treated as zero
    exactly as the dense Nyström path does.
    """
    good = evals > 1e-6 * jnp.max(evals)
    inv = jnp.where(good, 1.0 / jnp.maximum(evals, _EPS), 0.0)
    return (evecs * jnp.sqrt(inv)[None, :]) @ evecs.T
