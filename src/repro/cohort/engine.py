"""CohortEngine — the select–cluster–cache lifecycle, in one place.

Before this subsystem existed the lifecycle was smeared across
``core/selection.py`` (fingerprint cache, implicit PRNG threading,
auto-k double-compute) and ``core/spectral.py`` (landmark sampling baked
into the embedding).  The engine owns all of it:

* **method resolution** — ``dense`` below ``dense_cutoff`` clients,
  ``sharded`` (distributed Nyström over a client mesh — a jitted 1-way
  mesh when only one device is visible) above it; ``nystrom`` is the
  eager single-device reference path.  Pin any of them explicitly.
* **landmark quality** — pluggable ``uniform | leverage | kmeans++``
  strategies (``cohort/landmarks.py``).
* **determinism** — every solve's PRNG key is ``fold_in(base_key,
  fingerprint(embeds))``, a pure function of the engine seed and the
  embedding content.  Re-clustering the same embeddings is bit-identical
  no matter what happened in between (the PR 1 key stream mutated per
  call, so it wasn't).
* **caching and warm starts** — an exact content fingerprint short-
  circuits repeated solves within a round; between rounds, a cheap
  moment/sign-weighted sketch measures embedding drift against the
  last cold solve, and while cumulative drift stays under
  ``drift_threshold`` the engine reuses that solve's landmarks +
  bandwidth and warm-starts the blocked subspace solvers from the
  persisted eigenbases in ``CohortState``; once accumulated drift
  crosses the threshold, the next solve is cold and the baseline
  refreshes.

Public API: ``CohortEngine(config, seed=...)``, ``engine.select(embeds)
-> CohortResult``, ``engine.reset()``, ``engine.stats``.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cohort.landmarks import LANDMARK_STRATEGIES, select_landmarks
from repro.cohort.nystrom import nystrom_from_landmarks
from repro.core import spectral as _spectral
from repro.core.kmeans import kmeans, pairwise_sq_dists
from repro.core.spectral import row_normalize

_METHODS = ("auto", "dense", "nystrom", "sharded")
_SKETCH_EPS = 1e-12
# autotuning only ever reads the last two gaps; keep a short tail for
# debugging but never let a long-running server grow the list unboundedly
_GAP_HIST_MAX = 32

# landmark-count autotuning (num_landmarks="auto"): relative eigengap
# g = (λ_{k+1} − λ_k) / (λ_{k+1} − λ_1) — the share of the approximate
# L_norm spectral spread concentrated in the k -> k+1 gap.  Empirically
# (see docs/ARCHITECTURE.md) well-separated cohorts sit around 0.1 at
# any sufficient m while unstructured/under-resolved kernels sit below
# 0.01, so: below _GAP_WEAK the landmark set is judged too coarse (m
# doubles); above _GAP_STRONG twice in a row, with only moderate drift,
# it is judged wasteful (m halves toward the base).
_GAP_WEAK = 0.02
_GAP_STRONG = 0.08
_AUTO_M_MAX_FACTOR = 8     # cap: 8x the static default, clipped to n
_AUTO_M_DRIFT_FACTOR = 4   # shrink only when drift <= 4x drift_threshold


@dataclasses.dataclass
class CohortConfig:
    """Knobs of the cohort-selection engine (see module docstring).

    num_clusters     — k: spectral-embedding width and DQN action count.
    method           — "auto" | "dense" | "nystrom" | "sharded".
    num_landmarks    — m for the Nyström paths: an int pins it, None
                       uses the static default max(8k, 64), "auto"
                       autotunes m between that default and 8x it from
                       the drift sketch + relative-eigengap history
                       (weak gap doubles m; two consecutive strong gaps
                       under moderate drift halve it).
    landmarks        — "uniform" | "leverage" | "kmeans++" strategy.
    solver           — landmark eigenproblems: "auto" picks dense eigh
                       for m <= eigh_cutoff, blocked subspace iteration
                       above; "eigh" / "subspace" pin it.
    dense_solver     — dense-path eigensolver ("eigh" | "subspace").
    auto_k           — eigengap heuristic caps the cluster count k̂ <= k.
    warm_start       — enable drift-gated incremental re-clustering.
    drift_threshold  — relative sketch distance below which the previous
                       round's landmarks/bandwidth/eigenbases are reused.
    cold_iters/warm_iters — subspace sweeps from random / persisted q0.
    dense_cutoff     — "auto" method: largest N solved densely.
    eigh_cutoff      — "auto" solver: largest m factored with dense eigh.
    w_rank           — rank of the blocked W^{-1/2} (default max(8k, 64)).
    block_rows       — row-panel height inside the blocked eigensolver.
    use_pallas       — route the landmark paths through the streaming
                       fused Pallas pipeline (the (N, m) cross-affinity
                       is never materialized) and the dense path's
                       affinity kernels through Pallas.
    affinity_dtype   — "f32" | "bf16" | "int8": tile precision of the
                       fused affinity passes (per-row quantization
                       scales, f32/int32 MXU accumulation).  Non-f32
                       requires use_pallas=True — the jnp reference
                       path is the exact f32 oracle.
    """
    num_clusters: int = 8
    method: str = "auto"
    num_landmarks: Optional[object] = None     # int | None | "auto"
    landmarks: str = "uniform"
    solver: str = "auto"
    dense_solver: str = "eigh"
    auto_k: bool = False
    warm_start: bool = True
    drift_threshold: float = 0.05
    cold_iters: int = 40
    warm_iters: int = 8
    dense_cutoff: int = 2048
    eigh_cutoff: int = 2048
    w_rank: Optional[int] = None
    block_rows: int = 2048
    use_pallas: bool = False
    affinity_dtype: str = "f32"

    def __post_init__(self):
        if self.affinity_dtype not in ("f32", "bf16", "int8"):
            raise ValueError(
                f"unknown affinity_dtype {self.affinity_dtype!r}; "
                f"expected one of ('f32', 'bf16', 'int8')")
        if self.affinity_dtype != "f32" and not self.use_pallas:
            raise ValueError(
                f"affinity_dtype={self.affinity_dtype!r} requires "
                f"use_pallas=True (quantized tiles only exist in the "
                f"fused Pallas pipeline)")
        if self.method not in _METHODS:
            raise ValueError(f"unknown method {self.method!r}; "
                             f"expected one of {_METHODS}")
        if self.landmarks not in LANDMARK_STRATEGIES:
            raise ValueError(
                f"unknown landmark strategy {self.landmarks!r}; "
                f"expected one of {LANDMARK_STRATEGIES}")
        if self.solver not in ("auto", "eigh", "subspace"):
            raise ValueError(f"unknown solver {self.solver!r}")
        m = self.num_landmarks
        if not (m is None or m == "auto"
                or (isinstance(m, (int, np.integer)) and m > 0)):
            raise ValueError(
                f"num_landmarks={m!r} must be a positive int, None, "
                f"or \"auto\"")


@dataclasses.dataclass
class CohortState:
    """Engine-owned per-round memory: the warm-start payload.

    ``fingerprint`` short-circuits exact re-clustering; ``sketch`` is the
    drift baseline (the embedding sketch at the last COLD solve);
    ``landmark_idx``/``gamma`` pin the kernel between warm rounds;
    ``w_basis``/``mm_basis`` seed the subspace solvers.
    """
    fingerprint: Optional[bytes] = None
    sketch: Optional[np.ndarray] = None
    num_clients: int = 0
    landmark_idx: Optional[np.ndarray] = None
    gamma: Optional[float] = None
    w_basis: Optional[np.ndarray] = None
    mm_basis: Optional[np.ndarray] = None
    result: Optional["CohortResult"] = None


@dataclasses.dataclass
class CohortResult:
    """One cohort clustering: assignments plus provenance."""
    assign: np.ndarray            # (n,) cluster ids in [0, k)
    k: int                        # clusters actually used (k̂ if auto_k)
    embedding: np.ndarray         # (n, k) row-normalized spectral embedding
    evals: np.ndarray             # approximate L_norm spectrum, ascending
    method: str                   # resolved: dense | nystrom | sharded
    source: str                   # "cold" | "warm" | "cache"
    drift: float                  # relative sketch drift vs last cold baseline
    seconds: float                # wall time of this solve (0 on cache hit)


@dataclasses.dataclass
class PreparedSolve:
    """A finished solve staged for publication (the solve-ahead payload).

    :meth:`CohortEngine.prepare` computes one of these **without**
    touching any serving-visible engine state — no fingerprint-cache
    entry, no warm-start baseline, no counters.  A later
    :meth:`CohortEngine.publish` installs it atomically (from the
    caller's locking point of view: publish is a handful of reference
    assignments).  This is what lets a background solver warm version
    v+1 while the serving path keeps replaying version v's result from
    the cache, then swap.
    """
    fingerprint: bytes
    sketch: np.ndarray
    num_clients: int
    result: CohortResult
    landmark_idx: Optional[np.ndarray]
    gamma: Optional[float]
    w_basis: Optional[np.ndarray]
    mm_basis: Optional[np.ndarray]
    warm: bool                    # warm-started off the state it saw
    drift: float
    # k+1-wide L_norm spectrum for the landmark autotuner (only set for
    # cold landmark solves under num_landmarks="auto")
    auto_m_evals: Optional[np.ndarray] = None


class CohortEngine:
    """Owns the full select–cluster–cache lifecycle for cohort selection.

    ``select(embeds)`` clusters the (N, d) client embeddings and returns
    a :class:`CohortResult`; policies sample their cohort from
    ``result.assign``.  Determinism contract: every COLD solve is a pure
    function of ``(seed, embeds)`` — the PRNG key is derived from the
    content fingerprint, never from call history, so re-clustering the
    same embeddings cold is bit-identical.  Warm starts deliberately
    trade that for speed (they reuse the previous round's landmarks);
    they only fire below ``drift_threshold`` and can be disabled with
    ``warm_start=False`` for strict reproducibility.
    """

    def __init__(self, config: Optional[CohortConfig] = None, *,
                 seed: int = 0, mesh=None):
        self.config = config or CohortConfig()
        self.base_key = jax.random.PRNGKey(seed)
        self._sketch_sign: Optional[np.ndarray] = None
        self._sketch_seed = seed ^ 0x5EED
        self._mesh = mesh
        self.state = CohortState()
        self._auto_m: Optional[int] = None     # autotuned landmark count
        # relative eigengaps of recent cold solves (bounded: a server
        # calling select every round forever must not leak memory here)
        self._gap_hist: "collections.deque" = collections.deque(
            maxlen=_GAP_HIST_MAX)
        self.stats = {"solves": 0, "cache_hits": 0, "warm_starts": 0,
                      "cold_starts": 0, "probes": 0,
                      "batched_selects": 0, "coalesced_requests": 0}

    # -- state ----------------------------------------------------------
    def reset(self) -> None:
        """Drop all cached/warm-start state (e.g. on client churn)."""
        self.state = CohortState()

    @staticmethod
    def fingerprint(embeds: np.ndarray) -> bytes:
        """Content fingerprint of an embedding table (shape-qualified).

        Public because the streaming layer keys cross-tenant solve
        dedupe on it: two tenants whose tables hash identically can ride
        one background solve (``repro.streaming.SolveDeduper``).
        """
        h = hashlib.sha1(np.ascontiguousarray(embeds).tobytes())
        h.update(str(embeds.shape).encode())
        return h.digest()

    _fingerprint = fingerprint                   # pre-streaming spelling

    def _sketch(self, embeds: np.ndarray) -> np.ndarray:
        """O(n·d) drift probe: column moments + a sign-weighted row sum.

        The fixed ±1 row weighting keeps the probe sensitive to
        per-client movement that leaves the global moments unchanged
        (e.g. two clients swapping embeddings).
        """
        n = embeds.shape[0]
        if self._sketch_sign is None or len(self._sketch_sign) != n:
            rng = np.random.default_rng(self._sketch_seed)
            self._sketch_sign = rng.choice(
                np.array([-1.0, 1.0], np.float32), size=n)
        return np.concatenate([
            embeds.mean(axis=0), embeds.std(axis=0),
            (self._sketch_sign[:, None] * embeds).mean(axis=0)])

    # -- resolution -----------------------------------------------------
    def _resolve_method(self, n: int) -> str:
        if self.config.method != "auto":
            return self.config.method
        if n <= self.config.dense_cutoff:
            return "dense"
        # above the dense cutoff, always the mesh path — on a single
        # device it degenerates to the same math on a 1-way mesh, but
        # runs fully jitted (the eager "nystrom" path pays ~1.8x
        # dispatch/materialization overhead at N=100k; it remains the
        # bit-identical-to-interpret-Pallas reference path).
        return "sharded"

    def _resolve_solver(self, m: int) -> str:
        if self.config.solver != "auto":
            return self.config.solver
        return "eigh" if m <= self.config.eigh_cutoff else "subspace"

    def _cohort_mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_cohort_mesh
            self._mesh = make_cohort_mesh()
        return self._mesh

    # -- solve ----------------------------------------------------------
    def select(self, embeds, *, key=None) -> CohortResult:
        """Cluster the (N, d) client embeddings; cache- and drift-aware.

        ``key`` overrides the content-derived PRNG key (advanced; the
        default already makes repeat calls bit-identical).  An explicit
        key makes the call a one-off probe: it bypasses the fingerprint
        cache AND leaves the engine's cache/warm-start state untouched,
        so the default stream's (seed, embeds) purity is preserved.
        Probes are also invisible to the persistent serving counters
        (``solves`` / ``cold_starts`` / ``warm_starts``) — a dashboard
        reading :attr:`stats` sees only real serving traffic; probes
        count under ``stats["probes"]``.
        """
        embeds = np.ascontiguousarray(np.asarray(embeds, np.float32))
        st = self.state
        fp = self.fingerprint(embeds)
        persist = key is None
        if persist and st.fingerprint == fp and st.result is not None:
            self.stats["cache_hits"] += 1
            cached = st.result
            return dataclasses.replace(
                cached, source="cache", seconds=0.0,
                # copies: the cached arrays back every future replay, a
                # caller mutating its result must not corrupt them
                assign=cached.assign.copy(),
                embedding=cached.embedding.copy(),
                evals=cached.evals.copy())
        prep = self._prepare(embeds, fp, key=key, warm_ok=persist)
        if persist:
            self.publish(prep)
        else:
            self.stats["probes"] += 1
        return prep.result

    # -- solve-ahead (the streaming double-buffer entry points) ----------
    def prepare(self, embeds) -> Optional[PreparedSolve]:
        """Solve without mutating serving-visible caches.

        Returns the staged :class:`PreparedSolve` for a later
        :meth:`publish`, or ``None`` when the engine's cache is already
        current for these exact embeddings (nothing to warm).  Warm-start
        eligibility is read from the state the engine holds *now* — the
        canonical caller (``repro.streaming.BackgroundSolver``) serializes
        all engine entries on the server's ``_solve_lock``, so the state
        it sees is the last published solve.
        """
        embeds = np.ascontiguousarray(np.asarray(embeds, np.float32))
        fp = self.fingerprint(embeds)
        if self.state.fingerprint == fp and self.state.result is not None:
            return None
        return self._prepare(embeds, fp, key=None, warm_ok=True)

    def publish(self, prep: PreparedSolve, *, count: bool = True,
                ) -> CohortResult:
        """Install a staged solve as the engine's current state.

        This is the only place a :meth:`prepare` output becomes visible
        to the fingerprint cache and the warm-start baseline.  ``count=
        False`` installs without bumping the solve counters — used when a
        deduped solve computed by another tenant's engine is adopted, so
        "exactly one engine solve" stays true on dashboards.
        """
        st = self.state
        st.fingerprint, st.num_clients = prep.fingerprint, prep.num_clients
        if not prep.warm:
            st.sketch = prep.sketch          # new cold baseline
        st.landmark_idx = prep.landmark_idx
        st.gamma = prep.gamma
        st.w_basis = prep.w_basis
        st.mm_basis = prep.mm_basis
        st.result = prep.result
        if count:
            self.stats["warm_starts" if prep.warm else "cold_starts"] += 1
            self.stats["solves"] += 1
            if prep.auto_m_evals is not None:
                self._update_auto_m(prep.num_clients,
                                    self.config.num_clusters,
                                    prep.drift, prep.auto_m_evals)
        return prep.result

    def _prepare(self, embeds: np.ndarray, fp: bytes, *, key,
                 warm_ok: bool) -> PreparedSolve:
        """The full solve, staged: reads engine state, never writes it."""
        cfg = self.config
        st = self.state
        t0 = time.perf_counter()
        n = embeds.shape[0]
        method = self._resolve_method(n)
        if key is None:
            key = jax.random.fold_in(
                self.base_key, int.from_bytes(fp[:4], "little"))
        land_key, solve_key, km_key = jax.random.split(key, 3)

        # drift is measured against the sketch of the last COLD solve,
        # not the previous round: warm rounds do not advance the
        # baseline, so slow per-round drift ACCUMULATES and eventually
        # forces a cold refresh of landmarks + bandwidth (otherwise the
        # round-0 kernel would be reused forever under steady drift).
        sketch = self._sketch(embeds)
        drift = float("inf")
        if st.sketch is not None and st.num_clients == n:
            drift = float(np.linalg.norm(sketch - st.sketch)
                          / (np.linalg.norm(st.sketch) + _SKETCH_EPS))

        x = jnp.asarray(embeds)
        k = cfg.num_clusters
        # auto_k and landmark autotuning both need the lambda_k /
        # lambda_{k+1} gap, but the subspace solvers only return as many
        # eigenvalues as the embedding width — so solve one wider and
        # slice back after the gap is read off.
        widen = cfg.auto_k or (self._autotune_m and method != "dense")
        solve_k = k + 1 if widen else k
        if method == "dense":
            y, evals = self._solve_dense(x, solve_k)
            warm = False
            idx = gamma = w_basis = mm_basis = None
        else:
            y, evals, warm, idx, gamma, w_basis, mm_basis = \
                self._solve_landmarks(x, solve_k, method, drift,
                                      land_key, solve_key, warm_ok=warm_ok)
        auto_m_evals = (np.asarray(evals)
                        if self._autotune_m and method != "dense"
                        and not warm else None)

        k_hat = k
        if cfg.auto_k:
            k_hat = int(np.clip(
                int(_spectral.eigengap_k(evals, k)), 2, k))
            y = row_normalize(y[:, :k_hat])
        elif widen:
            y = row_normalize(y[:, :k])
        assign, _ = kmeans(km_key, y, k_hat)

        result = CohortResult(
            assign=np.asarray(assign), k=k_hat,
            embedding=np.asarray(y), evals=np.asarray(evals),
            method=method, source="warm" if warm else "cold", drift=drift,
            seconds=time.perf_counter() - t0)
        return PreparedSolve(
            fingerprint=fp, sketch=sketch, num_clients=n, result=result,
            landmark_idx=None if idx is None else np.asarray(idx),
            gamma=None if gamma is None else float(gamma),
            w_basis=None if w_basis is None else np.asarray(w_basis),
            mm_basis=None if mm_basis is None else np.asarray(mm_basis),
            warm=warm, drift=drift, auto_m_evals=auto_m_evals)

    def select_batched(self, embeds, *, requests: int = 1) -> CohortResult:
        """One solve serving ``requests`` coalesced select calls.

        The batched serving path (``CohortServer.select_cohorts`` /
        ``CohortFrontend``) funnels every concurrent request against one
        embedding-table version through a single engine entry; this
        wrapper is that entry.  The clustering work is identical to
        :meth:`select` — same cache, same warm-start state, same
        determinism contract — but the ``batched_selects`` /
        ``coalesced_requests`` counters record the coalescing so
        ``requests / batched_selects`` reads as the realized batch
        factor on a dashboard.
        """
        if requests < 1:
            raise ValueError(f"requests={requests} must be >= 1")
        result = self.select(embeds)
        self.stats["batched_selects"] += 1
        self.stats["coalesced_requests"] += requests
        return result

    def _solve_dense(self, x, k: int):
        a = _spectral.affinity_matrix(x, use_pallas=self.config.use_pallas)
        return _spectral.spectral_embedding(
            a, k, solver=self.config.dense_solver)

    @property
    def _autotune_m(self) -> bool:
        return self.config.num_landmarks == "auto"

    def _num_landmarks(self, n: int, k: int) -> int:
        if self._autotune_m:
            # base off the configured cluster count, NOT the (possibly
            # k+1-widened) solve width, so the recorded _auto_m always
            # equals the m actually solved with — otherwise the next
            # round's warm-start size check can never match
            m = self._auto_m or _spectral.default_num_landmarks(
                n, self.config.num_clusters)
        else:
            m = (self.config.num_landmarks
                 or _spectral.default_num_landmarks(n, k))
        m = min(int(m), n)
        if m < k:
            raise ValueError(f"num_landmarks={m} must be >= k={k}")
        return m

    def _update_auto_m(self, n: int, k: int, drift: float,
                       evals: np.ndarray) -> None:
        """Adapt the landmark count from eigengap + drift evidence.

        Called after every COLD landmark solve (warm solves must keep m
        fixed — the warm-start check requires the persisted landmark set
        to match).  The solve is run one eigenvector wide (k+1) so the
        relative gap  g = (λ_{k+1} − λ_k)/(λ_{k+1} − λ_1)  of the
        approximate L_norm spectrum is observable: a weak gap means the
        Nyström approximation is not resolving the k-cluster structure,
        so m doubles (up to 8x the static default); two consecutive
        strong gaps under moderate sketch drift mean the kernel is over-
        resolved, so m halves back toward the default.
        """
        evals = np.asarray(evals)
        if len(evals) <= k:           # no λ_{k+1}: nothing to measure
            return
        lo, hi = float(evals[k - 1]), float(evals[k])
        gap = max(hi - lo, 0.0) / max(hi - float(evals[0]), _SKETCH_EPS)
        self._gap_hist.append(gap)
        base = _spectral.default_num_landmarks(n, k)
        cap = min(n, _AUTO_M_MAX_FACTOR * base)
        m = self._auto_m or base
        if gap < _GAP_WEAK:
            m = min(cap, 2 * m)
        elif (len(self._gap_hist) >= 2
              and min(list(self._gap_hist)[-2:]) > _GAP_STRONG
              and np.isfinite(drift)
              and drift <= _AUTO_M_DRIFT_FACTOR
              * self.config.drift_threshold):
            m = max(base, m // 2)
        self._auto_m = m
        self.stats["auto_m"] = m

    def _solve_landmarks(self, x, k: int, method: str, drift: float,
                         land_key, solve_key, *, warm_ok: bool = True):
        cfg, st = self.config, self.state
        n = x.shape[0]
        m = self._num_landmarks(n, k)
        solver = self._resolve_solver(m)
        # warm = reuse the previous round's landmarks + bandwidth; with
        # subspace solvers the persisted eigenbases additionally seed q0
        # and the iteration count drops to warm_iters.  Keyed probes
        # (warm_ok=False) never warm-start: the caller's key must fully
        # determine the solve, not the persisted landmark state.  Reads
        # the persisted state, never writes it — publication of the
        # landmark set is CohortEngine.publish's job.
        warm = (warm_ok and cfg.warm_start
                and drift <= cfg.drift_threshold
                and st.landmark_idx is not None
                and len(st.landmark_idx) == m and st.gamma is not None)
        warm_basis = (warm and solver == "subspace"
                      and st.mm_basis is not None
                      and st.w_basis is not None)
        if warm:
            idx = jnp.asarray(st.landmark_idx)
            gamma = st.gamma
        else:
            idx = select_landmarks(land_key, x, m, cfg.landmarks)
            rows = x[:min(n, _spectral._GAMMA_SAMPLE_ROWS)]
            gamma = float(_spectral.auto_gamma(
                pairwise_sq_dists(rows, x[idx])))
        w_rank = (None if solver == "eigh"
                  else min(m, cfg.w_rank or max(8 * k, 64)))
        kwargs = dict(
            w_solver=solver, w_rank=w_rank, mm_solver=solver,
            iters=cfg.warm_iters if warm_basis else cfg.cold_iters,
            w_q0=jnp.asarray(st.w_basis) if warm_basis else None,
            mm_q0=jnp.asarray(st.mm_basis) if warm_basis else None,
            key=solve_key, block_rows=cfg.block_rows)
        # use_pallas routes the landmark solve through the streaming
        # fused pipeline: C is recomputed tile-by-tile in VMEM (never
        # materialized), at the configured affinity_dtype precision.
        if method == "sharded":
            from repro.cohort.sharded import sharded_nystrom_from_landmarks
            y, evals, mm_basis, w_basis = sharded_nystrom_from_landmarks(
                x, idx, k, gamma, self._cohort_mesh(),
                use_pallas=cfg.use_pallas, fused=cfg.use_pallas,
                affinity_dtype=cfg.affinity_dtype, **kwargs)
        else:
            y, evals, mm_basis, w_basis = nystrom_from_landmarks(
                x, idx, k, gamma, use_pallas=cfg.use_pallas,
                fused=cfg.use_pallas, affinity_dtype=cfg.affinity_dtype,
                **kwargs)
        return y, evals, warm, idx, gamma, w_basis, mm_basis
