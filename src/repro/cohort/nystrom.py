"""Landmark-explicit Nyström embedding — the math core of the engine.

``nystrom_from_landmarks`` is the one-shot Nyström extension (Fowlkes et
al., 2004) factored so the LANDMARK SET IS AN INPUT, not sampled inside:
the engine owns landmark selection (uniform / leverage / k-means++, see
``cohort/landmarks.py``) and warm-start state, and both the single-device
path here and the mesh-sharded path (``cohort/sharded.py``) consume the
same ``_nystrom_core`` body.  The core is written against an optional
``axis_name`` so the only difference between the two paths is a pair of
``lax.psum`` reductions over the client-row shards:

    col  = Σ_i C_ij            (m,)   — psum over row shards
    SᵀS  = Σ_shards S_sᵀ S_s   (m, m) — psum over row shards

Everything m-sized (the landmark block W, its inverse square root, the
normalized operator M and its eigenbasis) is replicated; everything
N-sized (C, S, the output embedding V) stays sharded.

``repro.core.spectral.nystrom_spectral_embedding`` delegates here, so
there is exactly one implementation of the extension in the tree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cohort.eigensolver import isqrt_from_eigs, topk_eigh
from repro.core.kmeans import pairwise_sq_dists
from repro.core.spectral import cross_affinity, row_normalize

_EPS = 1e-12


def _nystrom_core(c, w_isqrt, k: int, *, axis_name=None,
                  mm_solver: str = "eigh", mm_iters: int = 30,
                  mm_q0=None, key=None, block_rows: int = 2048):
    """Degree-normalize C, solve the m×m operator, extend to all rows.

    ``c`` is the (n_local, m) cross-affinity (the full (N, m) block on
    the single-device path, one row shard under ``shard_map``).  With
    ``axis_name`` set, the two cross-shard sums are ``psum``ed so every
    device sees the same m×m operator while its rows of S / V stay local.

    Returns ``(y_rownormed, evals_of_L_norm_ascending, mm_basis)`` where
    ``mm_basis`` is the top-k eigenbasis of M — the warm-start payload.
    """
    col = jnp.sum(c, axis=0)                                   # (m,)
    if axis_name is not None:
        col = jax.lax.psum(col, axis_name)
    # approximate degrees d̂ = C W⁺ (Cᵀ 1); W⁺ = W^{-1/2} W^{-1/2}
    d_hat = c @ (w_isqrt @ (w_isqrt @ col))
    s = c * jax.lax.rsqrt(jnp.maximum(d_hat, _EPS))[:, None]   # (n_l, m)
    sts = s.T @ s
    if axis_name is not None:
        sts = jax.lax.psum(sts, axis_name)
    mm = w_isqrt @ sts @ w_isqrt
    mm = 0.5 * (mm + mm.T)
    r = mm.shape[0] if mm_solver == "eigh" else k
    lam, top = topk_eigh(mm, r, solver=mm_solver, iters=mm_iters,
                         q0=mm_q0, key=key, block_rows=block_rows)
    basis = top[:, :k]
    v = (s @ (w_isqrt @ basis)) * jax.lax.rsqrt(
        jnp.maximum(lam[:k], _EPS))[None, :]                   # (n_l, k)
    evals = 1.0 - lam                                          # asc. L_norm
    return row_normalize(v), evals, basis


def landmark_block_isqrt(z, gamma, *, w=None, w_solver: str = "eigh",
                         w_rank: int | None = None, iters: int = 30,
                         w_q0=None, key=None, block_rows: int = 2048):
    """W^{-1/2} of the landmark affinity block, plus its eigenbasis.

    ``w`` overrides the affinity block (callers that already hold the
    landmark rows of C pass them to stay backend-consistent with C).
    ``w_solver="subspace"`` with ``w_rank`` r < m builds the rank-r
    pseudo-inverse square root from the blocked solver — the m ≥ 10⁴
    regime where dense eigh is not an option.  Returns
    ``(w_isqrt (m, m), w_basis (m, r))``.
    """
    m = z.shape[0]
    if w is None:
        w = jnp.exp(-gamma * pairwise_sq_dists(z, z))
    w = 0.5 * (w + w.T)
    r = m if w_solver == "eigh" else min(m, w_rank or m)
    ew, uw = topk_eigh(w, r, solver=w_solver, iters=iters, q0=w_q0,
                       key=key, block_rows=block_rows)
    return isqrt_from_eigs(ew, uw), uw


# NOT jitted at this level: under jit XLA re-fuses the jnp cross-affinity
# while the Pallas call stays opaque, and the ~1e-7 accumulation
# differences rotate the (degenerate) leading eigenspace arbitrarily.
# Eager, interpret-mode Pallas is bit-identical to the jnp formula, and
# callers inside jit contexts (spectral_cluster) trace this anyway.
# The eager dispatch costs ~1.8x wall-clock at N=100k — at that scale
# use the sharded path (fully jitted; a 1-way mesh on one device),
# which the engine's "auto" method resolution does by default.
def nystrom_from_landmarks(x, idx, k: int, gamma, *,
                           use_pallas: bool = False,
                           w_solver: str = "eigh",
                           w_rank: int | None = None,
                           mm_solver: str = "eigh", iters: int = 30,
                           w_q0=None, mm_q0=None, key=None,
                           block_rows: int = 2048):
    """Nyström normalized-Laplacian embedding from an explicit landmark set.

    x: (n, d) points; idx: (m,) landmark indices into x; gamma: RBF
    bandwidth (explicit — the engine owns the heuristic so warm starts
    can pin it).  Returns ``(y, evals, mm_basis, w_basis)``:

    * ``y`` — (n, k) row-normalized embedding (rows of V);
    * ``evals`` — ascending spectrum of the approximate L_norm (length m
      for ``mm_solver="eigh"``, k for ``"subspace"``);
    * ``mm_basis`` / ``w_basis`` — the two eigenbases a later call can
      warm-start from (``mm_q0`` / ``w_q0``).
    """
    x = x.astype(jnp.float32)
    z = x[idx]
    if key is not None:
        w_key, mm_key = jax.random.split(key)
    else:
        w_key = mm_key = None
    c = cross_affinity(x, z, gamma=gamma, use_pallas=use_pallas)  # (n, m)
    # W = the landmark rows of C (not recomputed from z): keeping W on
    # the same backend/accumulation as C keeps the two consistent inside
    # the degenerate leading eigenspace a well-separated clustering has.
    w_isqrt, w_basis = landmark_block_isqrt(
        z, gamma, w=c[idx], w_solver=w_solver, w_rank=w_rank,
        iters=iters, w_q0=w_q0, key=w_key, block_rows=block_rows)
    y, evals, basis = _nystrom_core(
        c, w_isqrt, k, mm_solver=mm_solver, mm_iters=iters, mm_q0=mm_q0,
        key=mm_key, block_rows=block_rows)
    return y, evals, basis, w_basis
