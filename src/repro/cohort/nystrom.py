"""Landmark-explicit Nyström embedding — the math core of the engine.

``nystrom_from_landmarks`` is the one-shot Nyström extension (Fowlkes et
al., 2004) factored so the LANDMARK SET IS AN INPUT, not sampled inside:
the engine owns landmark selection (uniform / leverage / k-means++, see
``cohort/landmarks.py``) and warm-start state, and both the single-device
path here and the mesh-sharded path (``cohort/sharded.py``) consume the
same ``_nystrom_core`` body.  The core is written against an optional
``axis_name`` so the only difference between the two paths is a pair of
``lax.psum`` reductions over the client-row shards:

    col  = Σ_i C_ij            (m,)   — psum over row shards
    SᵀS  = Σ_shards S_sᵀ S_s   (m, m) — psum over row shards

Everything m-sized (the landmark block W, its inverse square root, the
normalized operator M and its eigenbasis) is replicated; everything
N-sized (C, S, the output embedding V) stays sharded.

``repro.core.spectral.nystrom_spectral_embedding`` delegates here, so
there is exactly one implementation of the extension in the tree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.cohort.eigensolver import isqrt_from_eigs, topk_eigh
from repro.core.kmeans import pairwise_sq_dists
from repro.core.spectral import cross_affinity, row_normalize

_EPS = 1e-12


def _nystrom_core(c, w_isqrt, k: int, *, axis_name=None,
                  mm_solver: str = "eigh", mm_iters: int = 30,
                  mm_q0=None, key=None, block_rows: int = 2048):
    """Degree-normalize C, solve the m×m operator, extend to all rows.

    ``c`` is the (n_local, m) cross-affinity (the full (N, m) block on
    the single-device path, one row shard under ``shard_map``).  With
    ``axis_name`` set, the two cross-shard sums are ``psum``ed so every
    device sees the same m×m operator while its rows of S / V stay local.

    Returns ``(y_rownormed, evals_of_L_norm_ascending, mm_basis)`` where
    ``mm_basis`` is the top-k eigenbasis of M — the warm-start payload.
    """
    col = jnp.sum(c, axis=0)                                   # (m,)
    if axis_name is not None:
        col = jax.lax.psum(col, axis_name)
    # approximate degrees d̂ = C W⁺ (Cᵀ 1); W⁺ = W^{-1/2} W^{-1/2}
    d_hat = c @ (w_isqrt @ (w_isqrt @ col))
    s = c * jax.lax.rsqrt(jnp.maximum(d_hat, _EPS))[:, None]   # (n_l, m)
    sts = s.T @ s
    if axis_name is not None:
        sts = jax.lax.psum(sts, axis_name)
    mm = w_isqrt @ sts @ w_isqrt
    mm = 0.5 * (mm + mm.T)
    r = mm.shape[0] if mm_solver == "eigh" else k
    lam, top = topk_eigh(mm, r, solver=mm_solver, iters=mm_iters,
                         q0=mm_q0, key=key, block_rows=block_rows)
    basis = top[:, :k]
    v = (s @ (w_isqrt @ basis)) * jax.lax.rsqrt(
        jnp.maximum(lam[:k], _EPS))[None, :]                   # (n_l, k)
    evals = 1.0 - lam                                          # asc. L_norm
    return row_normalize(v), evals, basis


def _nystrom_core_fused(x, z, gamma, w_isqrt, k: int, *, mask=None,
                        axis_name=None, affinity_dtype: str = "f32",
                        mm_solver: str = "eigh", mm_iters: int = 30,
                        mm_q0=None, key=None, block_rows: int = 2048):
    """Streaming twin of ``_nystrom_core``: C never hits HBM.

    Same math, but the (n_local, m) cross-affinity is recomputed tile-by-
    tile inside three fused Pallas passes (``kernels/nystrom_pallas.py``)
    instead of being materialized and re-read: colsum → rotated SᵀS Gram
    → row-normalized extension.  ``x`` is the raw (n_local, d) rows (the
    affinity is fused in), ``mask`` zeroes padded rows, and the two
    ``psum`` points are identical to the unfused core — the Gram kernel's
    last-step ``W⁻¹ᐟ²·put·W⁻¹ᐟ²`` rotation is linear, so psum-of-rotated
    equals rotated-psum.  ``affinity_dtype`` picks the tile precision
    (f32 / bf16 / int8 — see the kernel module).
    """
    from repro.kernels import ops as kernel_ops
    col = kernel_ops.nystrom_colsum(x, z, gamma, mask,
                                    affinity_dtype=affinity_dtype)
    if axis_name is not None:
        col = jax.lax.psum(col, axis_name)
    u = w_isqrt @ (w_isqrt @ col)                              # (m,)
    mm = kernel_ops.nystrom_gram(x, z, gamma, u, w_isqrt, mask,
                                 affinity_dtype=affinity_dtype)
    if axis_name is not None:
        mm = jax.lax.psum(mm, axis_name)
    mm = 0.5 * (mm + mm.T)
    r = mm.shape[0] if mm_solver == "eigh" else k
    lam, top = topk_eigh(mm, r, solver=mm_solver, iters=mm_iters,
                         q0=mm_q0, key=key, block_rows=block_rows,
                         use_pallas=True)
    basis = top[:, :k]
    proj = (w_isqrt @ basis) * jax.lax.rsqrt(
        jnp.maximum(lam[:k], _EPS))[None, :]                   # (m, k)
    v = kernel_ops.nystrom_extension(x, z, gamma, u, proj, mask,
                                     affinity_dtype=affinity_dtype)
    evals = 1.0 - lam                                          # asc. L_norm
    return v, evals, basis


def landmark_block_isqrt(z, gamma, *, w=None, w_solver: str = "eigh",
                         w_rank: int | None = None, iters: int = 30,
                         w_q0=None, key=None, block_rows: int = 2048,
                         use_pallas: bool = False):
    """W^{-1/2} of the landmark affinity block, plus its eigenbasis.

    ``w`` overrides the affinity block (callers that already hold the
    landmark rows of C pass them to stay backend-consistent with C).
    ``w_solver="subspace"`` with ``w_rank`` r < m builds the rank-r
    pseudo-inverse square root from the blocked solver — the m ≥ 10⁴
    regime where dense eigh is not an option.  Returns
    ``(w_isqrt (m, m), w_basis (m, r))``.
    """
    m = z.shape[0]
    if w is None:
        w = jnp.exp(-gamma * pairwise_sq_dists(z, z))
    w = 0.5 * (w + w.T)
    r = m if w_solver == "eigh" else min(m, w_rank or m)
    ew, uw = topk_eigh(w, r, solver=w_solver, iters=iters, q0=w_q0,
                       key=key, block_rows=block_rows,
                       use_pallas=use_pallas)
    return isqrt_from_eigs(ew, uw), uw


# NOT jitted at this level: under jit XLA re-fuses the jnp cross-affinity
# while the Pallas call stays opaque, and the ~1e-7 accumulation
# differences rotate the (degenerate) leading eigenspace arbitrarily.
# Eager, interpret-mode Pallas is bit-identical to the jnp formula, and
# callers inside jit contexts (spectral_cluster) trace this anyway.
# The eager dispatch costs ~1.8x wall-clock at N=100k — at that scale
# use the sharded path (fully jitted; a 1-way mesh on one device),
# which the engine's "auto" method resolution does by default.
def nystrom_from_landmarks(x, idx, k: int, gamma, *,
                           use_pallas: bool = False,
                           fused: bool = False,
                           affinity_dtype: str = "f32",
                           w_solver: str = "eigh",
                           w_rank: int | None = None,
                           mm_solver: str = "eigh", iters: int = 30,
                           w_q0=None, mm_q0=None, key=None,
                           block_rows: int = 2048):
    """Nyström normalized-Laplacian embedding from an explicit landmark set.

    x: (n, d) points; idx: (m,) landmark indices into x; gamma: RBF
    bandwidth (explicit — the engine owns the heuristic so warm starts
    can pin it).  Returns ``(y, evals, mm_basis, w_basis)``:

    * ``y`` — (n, k) row-normalized embedding (rows of V);
    * ``evals`` — ascending spectrum of the approximate L_norm (length m
      for ``mm_solver="eigh"``, k for ``"subspace"``);
    * ``mm_basis`` / ``w_basis`` — the two eigenbases a later call can
      warm-start from (``mm_q0`` / ``w_q0``).

    ``fused=True`` runs the streaming Pallas pipeline instead — the
    (n, m) C block is never materialized and ``affinity_dtype`` selects
    the tile precision.  Numerically this is the same operator up to
    the tiled f32 summation order, which rotates the (degenerate)
    leading eigenspace: compare rotation-invariant quantities (``evals``,
    the ``y·yᵀ`` projector, cluster partitions), not raw embeddings.
    ``fused=False`` (the default) is the jnp-composed reference the
    tests pin the fused path against.
    """
    x = x.astype(jnp.float32)
    z = x[idx]
    if key is not None:
        w_key, mm_key = jax.random.split(key)
    else:
        w_key = mm_key = None
    if fused:
        from repro.kernels import ops as kernel_ops
        # W through the same quantized tile math as the streamed C
        # panels (per-row scales make it partition-independent), for the
        # same backend-consistency reason as the unfused ``c[idx]``.
        w = kernel_ops.quantized_cross_affinity(
            z, z, gamma, affinity_dtype=affinity_dtype)
        w_isqrt, w_basis = landmark_block_isqrt(
            z, gamma, w=w, w_solver=w_solver, w_rank=w_rank,
            iters=iters, w_q0=w_q0, key=w_key, block_rows=block_rows,
            use_pallas=True)
        y, evals, basis = _nystrom_core_fused(
            x, z, gamma, w_isqrt, k, affinity_dtype=affinity_dtype,
            mm_solver=mm_solver, mm_iters=iters, mm_q0=mm_q0,
            key=mm_key, block_rows=block_rows)
        return y, evals, basis, w_basis
    c = cross_affinity(x, z, gamma=gamma, use_pallas=use_pallas)  # (n, m)
    # W = the landmark rows of C (not recomputed from z): keeping W on
    # the same backend/accumulation as C keeps the two consistent inside
    # the degenerate leading eigenspace a well-separated clustering has.
    w_isqrt, w_basis = landmark_block_isqrt(
        z, gamma, w=c[idx], w_solver=w_solver, w_rank=w_rank,
        iters=iters, w_q0=w_q0, key=w_key, block_rows=block_rows)
    y, evals, basis = _nystrom_core(
        c, w_isqrt, k, mm_solver=mm_solver, mm_iters=iters, mm_q0=mm_q0,
        key=mm_key, block_rows=block_rows)
    return y, evals, basis, w_basis
