"""Sharded cohort-selection engine.

Owns the select–cluster–cache lifecycle of DQRE-SCnet's Algorithm I at
production cohort scale: distributed Nyström over a client-row mesh,
pluggable landmark quality (uniform / leverage / k-means++), blocked
warm-startable eigensolvers, and drift-gated incremental re-clustering.
See ``cohort/engine.py`` for the lifecycle and ROADMAP.md ("Cohort
engine") for the architecture sketch.
"""

from repro.cohort.engine import (CohortConfig, CohortEngine, CohortResult,
                                 CohortState, PreparedSolve)
from repro.cohort.eigensolver import subspace_topk, topk_eigh
from repro.cohort.landmarks import (LANDMARK_STRATEGIES, select_landmarks,
                                    uniform_landmarks, kmeanspp_landmarks,
                                    leverage_landmarks)
from repro.cohort.nystrom import nystrom_from_landmarks
from repro.cohort.sharded import sharded_nystrom_from_landmarks

__all__ = [
    "CohortConfig", "CohortEngine", "CohortResult", "CohortState",
    "PreparedSolve",
    "subspace_topk", "topk_eigh",
    "LANDMARK_STRATEGIES", "select_landmarks", "uniform_landmarks",
    "kmeanspp_landmarks", "leverage_landmarks",
    "nystrom_from_landmarks", "sharded_nystrom_from_landmarks",
]
