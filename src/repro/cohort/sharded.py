"""Mesh-sharded distributed Nyström: the 10⁵–10⁸-client path.

The (N, m) cross-affinity is the only N-sized object in the landmark
pipeline, so it is the only thing worth distributing: client rows are
sharded over a 1-D device mesh (``launch.mesh.make_cohort_mesh``) with
``shard_map``, each device computing its own (N/D, m) panel of C and S
and its rows of the output embedding V.  The m-sized pieces — the
landmark block W, its inverse square root, and the normalized operator
M — are replicated: W is factored once on the host (dense eigh, or the
blocked subspace solver of ``cohort/eigensolver.py`` when m ≥ 10⁴), and
M is assembled from an all-reduced SᵀS (one ``psum``) so every device
solves the identical m×m eigenproblem.  Communication per round is
exactly one (m,) psum + one (m, m) psum — independent of N.

Row counts that don't divide the mesh are zero-padded and masked: padded
rows contribute nothing to the column sums or SᵀS and are sliced off the
output.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.cohort.nystrom import (_nystrom_core, _nystrom_core_fused,
                                  landmark_block_isqrt)
from repro.core.spectral import cross_affinity

# jitted shard_map closures keyed on (mesh, k, mm_solver, warm, iters,
# block_rows, use_pallas, fused, affinity_dtype) — rebuilding the
# closure per call would retrace every round.
_SHARDED_FNS: dict = {}


def _build_sharded_fn(mesh, k: int, mm_solver: str, warm: bool,
                      iters: int, block_rows: int, use_pallas: bool,
                      fused: bool = False, affinity_dtype: str = "f32"):
    axis = mesh.axis_names[0]

    def body(x_s, mask_s, z, w_isqrt, gamma, mm_q0):
        if fused:
            # streaming pipeline: each shard's (N/D, m) C panel lives
            # only tile-by-tile in VMEM; the same two psums (col, SᵀS)
            # fire inside the fused core — the Gram kernel's last-step
            # W⁻¹ᐟ² rotation is linear, so per-shard rotated Grams sum
            # to the rotated global Gram.
            return _nystrom_core_fused(
                x_s, z, gamma, w_isqrt, k, mask=mask_s, axis_name=axis,
                affinity_dtype=affinity_dtype, mm_solver=mm_solver,
                mm_iters=iters, mm_q0=mm_q0 if warm else None,
                key=None, block_rows=block_rows)
        c = cross_affinity(x_s, z, gamma=gamma, use_pallas=use_pallas)
        c = c * mask_s[:, None]
        return _nystrom_core(
            c, w_isqrt, k, axis_name=axis, mm_solver=mm_solver,
            mm_iters=iters, mm_q0=mm_q0 if warm else None,
            key=None, block_rows=block_rows)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(), P(), P(), P()),
        out_specs=(P(axis, None), P(), P()),
        # pallas_call has no replication rule yet; the replicated (P())
        # outputs are psum-derived either way, so the check adds nothing
        # on the kernel path
        check_rep=not (use_pallas or fused))
    return jax.jit(fn)


def sharded_nystrom_from_landmarks(x, idx, k: int, gamma, mesh, *,
                                   use_pallas: bool = False,
                                   fused: bool = False,
                                   affinity_dtype: str = "f32",
                                   w_solver: str = "eigh",
                                   w_rank: int | None = None,
                                   mm_solver: str = "eigh",
                                   iters: int = 30, w_q0=None, mm_q0=None,
                                   key=None, block_rows: int = 2048):
    """Distributed twin of ``nystrom.nystrom_from_landmarks``.

    Same signature plus ``mesh`` (a 1-D mesh whose single axis shards
    client rows); same ``(y, evals, mm_basis, w_basis)`` return contract,
    with ``y`` materialized as a global array sharded over the mesh.
    Numerically the two paths differ only by the float summation order
    of the two psums, so outputs agree to f32 reduction tolerance.
    ``fused=True`` swaps the shard body for the streaming Pallas core
    (``affinity_dtype`` tile precision; no per-shard (N/D, m) C panel in
    HBM) — same psum structure, so the mesh communication is unchanged.
    """
    n = x.shape[0]
    x = jnp.asarray(x, jnp.float32)
    z = x[idx]
    if key is not None:
        w_key, mm_key = jax.random.split(key)
    else:
        w_key = mm_key = None
    # W on the same backend as the sharded C panels (see nystrom.py on
    # backend consistency inside the degenerate leading eigenspace)
    if fused:
        from repro.kernels import ops as kernel_ops
        w = kernel_ops.quantized_cross_affinity(
            z, z, gamma, affinity_dtype=affinity_dtype)
    else:
        w = cross_affinity(z, z, gamma=gamma, use_pallas=use_pallas)
    w_isqrt, w_basis = landmark_block_isqrt(
        z, gamma, w=w,
        w_solver=w_solver, w_rank=w_rank, iters=iters,
        w_q0=w_q0, key=w_key, block_rows=block_rows,
        use_pallas=fused or use_pallas)

    num_shards = mesh.devices.size
    pad = (-n) % num_shards
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    mask = (jnp.arange(n + pad) < n).astype(jnp.float32)

    m = int(idx.shape[0])
    warm = mm_q0 is not None
    if warm:
        q0 = jnp.asarray(mm_q0, jnp.float32)
    elif mm_solver == "subspace":
        q0 = jax.random.normal(mm_key if mm_key is not None
                               else jax.random.PRNGKey(0), (m, k),
                               jnp.float32)
    else:
        q0 = jnp.zeros((m, k), jnp.float32)        # unused placeholder

    cache_key = (mesh, k, mm_solver, warm or mm_solver == "subspace",
                 iters, block_rows, use_pallas, fused, affinity_dtype)
    if cache_key not in _SHARDED_FNS:
        _SHARDED_FNS[cache_key] = _build_sharded_fn(
            mesh, k, mm_solver, warm or mm_solver == "subspace", iters,
            block_rows, use_pallas, fused, affinity_dtype)
    y, evals, basis = _SHARDED_FNS[cache_key](
        xp, mask, z, w_isqrt, jnp.asarray(gamma, jnp.float32), q0)
    return y[:n], evals, basis, w_basis
