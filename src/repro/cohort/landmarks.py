"""Landmark selection strategies for the Nyström cohort path.

Uniform landmark sampling is unbiased but wasteful on the skewed non-IID
embedding distributions federated cohorts actually produce: a head
cluster holding 80 % of the clients soaks up ~80 % of the landmarks and
the tail clusters — exactly the clients DQRE-SCnet exists to de-bias
toward — are missed entirely, collapsing their Nyström embedding onto
the head.  Two standard remedies, both pluggable via
``select_landmarks(..., strategy=...)``:

* ``"kmeans++"`` — D² (farthest-point-weighted) sampling: each new
  landmark is drawn with probability proportional to its squared
  distance from the landmarks picked so far, so every well-separated
  mode receives a landmark regardless of its population.  Runs on a
  uniformly pre-sampled pool of ``pool_factor * m`` points with an
  incrementally maintained min-distance vector, so the cost is
  O(pool · m · d) rather than the naive O(n · m² · d).
* ``"leverage"`` — approximate ridge leverage scores (Musco & Musco,
  2017): score ℓ_i = c_iᵀ (W_p + λI)⁻¹ c_i against a uniform pilot set,
  then sample m landmarks ∝ ℓ without replacement.  Rare-mode points
  are poorly explained by the pilot kernel and receive high leverage.

Every strategy is a pure function of its PRNG key — repeated calls with
the same key return bit-identical index sets (the engine's determinism
contract depends on this).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.kmeans import pairwise_sq_dists

_EPS = 1e-12

#: pool oversampling factor for the kmeans++ strategy (see module doc).
_KPP_POOL_FACTOR = 32
#: pilot-set size cap for approximate leverage scores.
_LEVERAGE_PILOT_CAP = 512


@functools.partial(jax.jit, static_argnames=("m",))
def uniform_landmarks(key, x, m: int):
    """m indices sampled uniformly without replacement."""
    return jax.random.choice(key, x.shape[0], (m,), replace=False)


@functools.partial(jax.jit, static_argnames=("m",))
def kmeanspp_landmarks(key, x, m: int):
    """D²-sampled landmark indices (k-means++ seeding over a pool).

    The min-distance vector is updated incrementally against only the
    newest landmark, so each of the m rounds costs O(pool · d).
    """
    n = x.shape[0]
    pool_n = min(n, max(_KPP_POOL_FACTOR * m, 4 * m))
    pool_key, first_key, seq_key = jax.random.split(key, 3)
    pool_idx = jax.random.choice(pool_key, n, (pool_n,), replace=False)
    pool = x[pool_idx].astype(jnp.float32)

    first = jax.random.randint(first_key, (), 0, pool_n)
    picked0 = jnp.zeros((m,), jnp.int32).at[0].set(first)
    d0 = jnp.sum((pool - pool[first]) ** 2, axis=1)

    def body(i, carry):
        picked, dmin, k = carry
        k, sub = jax.random.split(k)
        probs = dmin / jnp.maximum(jnp.sum(dmin), _EPS)
        nxt = jax.random.choice(sub, pool_n, p=probs)
        d2 = jnp.sum((pool - pool[nxt]) ** 2, axis=1)
        return picked.at[i].set(nxt), jnp.minimum(dmin, d2), k

    picked, _, _ = jax.lax.fori_loop(1, m, body, (picked0, d0, seq_key))
    return pool_idx[picked]


@functools.partial(jax.jit, static_argnames=("m",))
def leverage_landmarks(key, x, m: int, *, gamma=None):
    """Indices sampled ∝ approximate ridge leverage of the RBF kernel.

    A uniform pilot set P (|P| ≤ 512) stands in for the full kernel:
    ℓ_i = c_iᵀ (W_P + λI)⁻¹ c_i with c_i the RBF affinity of point i to
    P and λ = tr(W_P)/|P| (the standard self-tuning ridge).  Computing ℓ
    for all n points is two (n, p) matmuls — O(n·p·d + n·p²).
    """
    from repro.core.spectral import auto_gamma

    n = x.shape[0]
    x = x.astype(jnp.float32)
    p = min(n, max(m, 256), _LEVERAGE_PILOT_CAP)
    pilot_key, draw_key = jax.random.split(key)
    pilot = x[jax.random.choice(pilot_key, n, (p,), replace=False)]

    d2 = pairwise_sq_dists(x, pilot)                      # (n, p)
    if gamma is None:
        gamma = auto_gamma(d2)
    c = jnp.exp(-gamma * d2)
    w = jnp.exp(-gamma * pairwise_sq_dists(pilot, pilot))  # (p, p)
    lam = jnp.trace(w) / p
    ew, uw = jnp.linalg.eigh(w + lam * jnp.eye(p, dtype=w.dtype))
    cu = c @ uw                                            # (n, p)
    scores = jnp.sum(cu * cu / jnp.maximum(ew, _EPS)[None, :], axis=1)
    probs = scores / jnp.maximum(jnp.sum(scores), _EPS)
    return jax.random.choice(draw_key, n, (m,), replace=False, p=probs)


LANDMARK_STRATEGIES = ("uniform", "kmeans++", "leverage")


def select_landmarks(key, x, m: int, strategy: str = "uniform", *,
                     gamma=None):
    """Dispatch to a landmark strategy; returns (m,) int indices into x."""
    if strategy == "uniform":
        return uniform_landmarks(key, x, m)
    if strategy == "kmeans++":
        return kmeanspp_landmarks(key, x, m)
    if strategy == "leverage":
        return leverage_landmarks(key, x, m, gamma=gamma)
    raise ValueError(
        f"unknown landmark strategy {strategy!r}; "
        f"expected one of {LANDMARK_STRATEGIES}")
