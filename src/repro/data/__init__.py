from repro.data.pipeline import (TokenDataConfig, synthetic_token_batches,
                                 make_batch_iterator, batch_specs)

__all__ = ["TokenDataConfig", "synthetic_token_batches",
           "make_batch_iterator", "batch_specs"]
