"""Sharded token pipeline for the LM training/serving examples.

Offline container: token streams are procedurally generated (a mixture of
n-gram-ish Markov chains so the LM has learnable structure, unlike uniform
noise).  ``make_batch_iterator`` yields global batches placed with the
mesh's batch sharding (``jax.make_array_from_process_local_data``-style via
``jax.device_put``), with double-buffered host prefetch.
"""

from __future__ import annotations

import dataclasses
import threading
from queue import Queue
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class TokenDataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 2          # Markov order of the synthetic stream


def _markov_tables(cfg: TokenDataConfig):
    rng = np.random.default_rng(cfg.seed)
    # sparse-ish transition structure: each context prefers ~8 successors
    k = min(cfg.vocab_size, 8)
    ctx = min(cfg.vocab_size, 512)
    succ = rng.integers(0, cfg.vocab_size, size=(ctx, k))
    return ctx, succ


def synthetic_token_batches(cfg: TokenDataConfig,
                            num_batches: Optional[int] = None):
    """Yields {tokens, labels} numpy batches (global shapes)."""
    ctx_n, succ = _markov_tables(cfg)
    rng = np.random.default_rng(cfg.seed + 1)
    i = 0
    while num_batches is None or i < num_batches:
        # vectorized Markov rollout
        toks = np.empty((cfg.global_batch, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, cfg.global_batch)
        for t in range(cfg.seq_len):
            ctx = toks[:, t] % ctx_n
            choice = rng.integers(0, succ.shape[1], cfg.global_batch)
            nxt = succ[ctx, choice]
            noise = rng.random(cfg.global_batch) < 0.1
            nxt = np.where(noise,
                           rng.integers(0, cfg.vocab_size, cfg.global_batch),
                           nxt)
            toks[:, t + 1] = nxt
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        i += 1


def batch_specs(mesh: Mesh, batch_size: int):
    from repro.models.sharding import batch_pspec
    return NamedSharding(mesh, batch_pspec(mesh, 2, 0, batch_size))


def make_batch_iterator(cfg: TokenDataConfig, mesh: Optional[Mesh] = None,
                        num_batches: Optional[int] = None,
                        prefetch: int = 2) -> Iterator[dict]:
    """Host-prefetched iterator of device-placed batches."""
    gen = synthetic_token_batches(cfg, num_batches)
    sharding = batch_specs(mesh, cfg.global_batch) if mesh is not None else None

    q: Queue = Queue(maxsize=prefetch)
    _DONE = object()

    def producer():
        for batch in gen:
            q.put(batch)
        q.put(_DONE)

    th = threading.Thread(target=producer, daemon=True)
    th.start()

    while True:
        batch = q.get()
        if batch is _DONE:
            return
        if sharding is not None:
            batch = {k: jax.device_put(v, sharding) for k, v in batch.items()}
        else:
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
        yield batch
