"""Decoder-only transformer covering dense / MoE / SSM / hybrid / VLM archs.

Layer heterogeneity (Jamba's 1-attn-per-8 interleave, DeepSeek's
first-3-dense-then-MoE, Mamba2's FFN-free blocks) is expressed as
**segments**: maximal runs of a repeating layer-type period.  Each segment's
parameters are stacked along a leading ``repeats`` axis and executed with
``lax.scan`` so compile time and HLO size stay O(period), not O(num_layers)
— essential for AOT-compiling a 61-layer 671B config on this container.

The LM head never materializes (B, S, vocab) logits for training: the loss
is computed by a sequence-chunked scan (``chunked_ce_loss``), keeping peak
logits memory at (B, chunk, vocab_shard).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import attention as A
from repro.models import mla as MLA
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models.sharding import constrain, constrain_batch

# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------


def layer_types(cfg):
    """Per-layer (mixer, ffn) type tags."""
    out = []
    for i in range(cfg.num_layers):
        if cfg.is_attn_layer(i):
            mixer = "mla" if cfg.use_mla else "attn"
        else:
            mixer = "ssm"
        if cfg.d_ff == 0 and not cfg.is_moe_layer(i):
            ffn = "none"
        else:
            ffn = "moe" if cfg.is_moe_layer(i) else "dense"
        out.append((mixer, ffn))
    return out


def build_plan(cfg):
    """Segments: list of (repeats, period_types tuple)."""
    types = layer_types(cfg)
    segments = []
    i = 0
    # leading non-periodic prefix (e.g. DeepSeek first-3 dense layers)
    fd = cfg.first_dense_layers
    if fd:
        # prefix is homogeneous by construction
        assert all(t == types[0] for t in types[:fd])
        segments.append((fd, (types[0],)))
        i = fd
    rest = types[i:]
    if not rest:
        return segments
    # find the smallest period that tiles the rest
    period = 1
    while period <= len(rest):
        if len(rest) % period == 0:
            pat = rest[:period]
            if all(rest[j] == pat[j % period] for j in range(len(rest))):
                break
        period += 1
    segments.append((len(rest) // period, tuple(rest[:period])))
    return segments


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block_init(key, cfg, mixer, ffn):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"mixer_norm": L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype)}
    if mixer == "attn":
        p["attn"] = A.attn_init(k1, cfg)
    elif mixer == "mla":
        p["mla"] = MLA.mla_init(k1, cfg)
    else:
        p["ssm"] = M.mamba_init(k1, cfg)
    if ffn == "dense":
        p["ffn_norm"] = L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype)
        p["ffn"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, act=cfg.mlp_act,
                              dtype=cfg.param_dtype)
    elif ffn == "moe":
        p["ffn_norm"] = L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype)
        p["moe"] = MOE.moe_init(k3, cfg)
    return p


def _block_cache(cfg, mixer, batch, max_seq, dtype=None):
    if mixer == "attn":
        return A.init_kv_cache(cfg, batch, max_seq, dtype)
    if mixer == "mla":
        return MLA.init_mla_cache(cfg, batch, max_seq, dtype)
    return M.init_mamba_cache(cfg, batch, dtype)


def _block_apply(p, cfg, h, mixer, ffn, *, positions, window,
                 cache=None, cache_pos=None):
    aux = jnp.zeros((), jnp.float32)
    hn = L.rmsnorm(p["mixer_norm"], h, cfg.norm_eps)
    if mixer == "attn":
        out, new_cache = A.attention(p["attn"], hn, cfg, positions=positions,
                                     window=window, cache=cache,
                                     cache_pos=cache_pos)
    elif mixer == "mla":
        out, new_cache = MLA.mla_attention(p["mla"], hn, cfg,
                                           positions=positions, window=window,
                                           cache=cache, cache_pos=cache_pos)
    else:
        out, new_cache = M.mamba_apply(p["ssm"], hn, cfg, cache=cache)
    h = h + out.astype(h.dtype)
    if ffn == "dense":
        hn = L.rmsnorm(p["ffn_norm"], h, cfg.norm_eps)
        h = h + L.mlp(p["ffn"], hn, act=cfg.mlp_act).astype(h.dtype)
    elif ffn == "moe":
        hn = L.rmsnorm(p["ffn_norm"], h, cfg.norm_eps)
        out, metrics = MOE.moe_apply(p["moe"], hn, cfg)
        h = h + out.astype(h.dtype)
        aux = aux + cfg.router_aux_weight * metrics["moe_aux_loss"] \
            + cfg.router_z_weight * metrics["moe_z_loss"]
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# Model init / forward
# ---------------------------------------------------------------------------


def init_lm(key, cfg):
    plan = build_plan(cfg)
    keys = jax.random.split(key, len(plan) + 3)
    params = {"embed": L.embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                                    dtype=cfg.param_dtype),
              "final_norm": L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab_size,
                                         dtype=cfg.param_dtype)
    segments = []
    for si, (repeats, types) in enumerate(plan):
        seg_keys = jax.random.split(keys[2 + si], repeats)
        blocks = []
        for pos, (mixer, ffn) in enumerate(types):
            pos_keys = jax.vmap(lambda k: jax.random.fold_in(k, pos))(seg_keys)
            blocks.append(jax.vmap(
                lambda k: _block_init(k, cfg, mixer, ffn))(pos_keys))
        segments.append({"blocks": tuple(blocks)})
    params["segments"] = segments
    if cfg.mtp_depth > 0:
        k_mtp = keys[-1]
        params["mtp"] = {
            "proj": L.dense_init(jax.random.fold_in(k_mtp, 0),
                                 2 * cfg.d_model, cfg.d_model,
                                 dtype=cfg.param_dtype),
            "norm": L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
            "block": _block_init(jax.random.fold_in(k_mtp, 1), cfg,
                                 "mla" if cfg.use_mla else "attn", "dense"
                                 if cfg.d_ff else "none"),
        }
    return params


def init_lm_cache(cfg, batch: int, max_seq: int, dtype=None):
    """Decode caches for ``batch`` independent request **slots**.

    Every cache leaf is stacked ``(repeats, batch, ...)``, so the batch
    axis (axis 1) is a slot table: slot ``i`` holds request ``i``'s KV
    rows (or SSM state) and nothing else.  Slots are independently
    resettable/re-fillable — :func:`lm_prefill_slot` zeroes one slot
    and prefills a new prompt into it without touching the others,
    which is what lets the continuous-batching scheduler
    (``repro.launch.serve.DecodeScheduler``) admit and retire requests
    mid-decode against one live cache tree.
    """
    caches = []
    for repeats, types in build_plan(cfg):
        blocks = []
        for mixer, _ in types:
            one = _block_cache(cfg, mixer, batch, max_seq, dtype)
            blocks.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (repeats, *x.shape)), one))
        caches.append({"blocks": tuple(blocks)})
    return caches


def cache_slot(caches, slot, width: int = 1):
    """Slice ``width`` slots starting at ``slot`` out of a cache tree.

    Cache leaves are ``(repeats, batch, ...)`` (see
    :func:`init_lm_cache`); this returns the same tree with batch axis
    ``width`` — a standalone cache for those slots.  ``slot`` may be a
    traced scalar, so the slice lowers inside jit.
    """
    return jax.tree.map(
        lambda x: jax.lax.dynamic_slice_in_dim(x, slot, width, axis=1),
        caches)


def write_cache_slot(caches, slot_caches, slot):
    """Write a width-w cache tree back into slots ``[slot, slot+w)``."""
    return jax.tree.map(
        lambda full, part: jax.lax.dynamic_update_slice_in_dim(
            full, part.astype(full.dtype), slot, axis=1),
        caches, slot_caches)


def lm_hidden(params, cfg, h, *, positions, window=None, caches=None,
              cache_pos=None, remat=False):
    """Run all blocks.  h: (B,S,d) embedded input.  Returns
    (normed hidden, new_caches or None, aux scalar)."""
    plan = build_plan(cfg)
    aux = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None

    for si, (repeats, types) in enumerate(plan):
        seg_params = params["segments"][si]["blocks"]
        seg_cache = caches[si]["blocks"] if caches is not None else None

        def body(carry, xs, types=types):
            h, aux = carry
            blk_params, blk_cache = xs
            new_blk_caches = []
            for pos, (mixer, ffn) in enumerate(types):
                c = blk_cache[pos] if blk_cache is not None else None
                h, nc, a = _block_apply(
                    blk_params[pos], cfg, h, mixer, ffn,
                    positions=positions, window=window, cache=c,
                    cache_pos=cache_pos)
                aux = aux + a
                new_blk_caches.append(nc)
            ys = tuple(new_blk_caches) if blk_cache is not None else None
            return (h, aux), ys

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (seg_params, seg_cache)
        (h, aux), seg_new_cache = jax.lax.scan((lambda c, x: body(c, x)),
                                               (h, aux), xs)
        if caches is not None:
            new_caches.append({"blocks": seg_new_cache})

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return h, new_caches, aux


def embed_inputs(params, cfg, tokens=None, prefix_embeds=None):
    """Token (and optional VLM/audio prefix) embedding -> (B, S, d)."""
    parts = []
    if prefix_embeds is not None:
        parts.append(prefix_embeds.astype(jnp.dtype(cfg.compute_dtype)))
    if tokens is not None:
        parts.append(L.embed(params["embed"], tokens).astype(
            jnp.dtype(cfg.compute_dtype)))
    h = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return constrain_batch(h)


def lm_logits(params, cfg, h):
    """Full logits — only for small S (decode / eval)."""
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], h)
    else:
        logits = L.dense(params["lm_head"], h)
    logits = constrain(logits, ("pod", "data"), None, "model")
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits.astype(jnp.float32)


def chunked_ce_loss(params, cfg, h, labels, mask=None, chunk: int = 512):
    """Cross-entropy over (B,S) without materializing (B,S,V) logits.

    Scans over sequence chunks; within a chunk the logits stay sharded over
    the ``model`` axis in the vocab dim (GSPMD inserts the reduction
    collectives for logsumexp / label gather).
    """
    B, S, d = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    n = (S + pad) // chunk
    h = h.reshape(B, n, chunk, d)
    labels = labels.reshape(B, n, chunk)
    mask = mask.reshape(B, n, chunk)

    def step(carry, xs):
        tot, cnt = carry
        hc, lc, mc = xs                                   # (B,chunk,·)
        logits = lm_logits(params, cfg, hc)               # (B,chunk,V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mc
        return (tot + jnp.sum(ce), cnt + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (jnp.moveaxis(h, 1, 0), jnp.moveaxis(labels, 1, 0),
         jnp.moveaxis(mask, 1, 0)))
    return tot / jnp.maximum(cnt, 1.0)


def mtp_loss(params, cfg, h, tokens, labels_next2, mask=None):
    """DeepSeek-V3 depth-1 multi-token-prediction auxiliary loss.

    Combines the main-path hidden state at position t with the embedding of
    token t+1 to predict token t+2.
    """
    if "mtp" not in params:
        return jnp.zeros((), jnp.float32)
    mp = params["mtp"]
    B, S, d = h.shape
    emb_next = L.embed(params["embed"], tokens).astype(h.dtype)
    hh = jnp.concatenate([L.rmsnorm(mp["norm"], h, cfg.norm_eps),
                          emb_next], axis=-1)
    hh = L.dense(mp["proj"], hh)
    positions = jnp.arange(S)
    hh2, _, _ = _apply_single_block(mp["block"], cfg, hh, positions)
    return chunked_ce_loss(params, cfg, hh2, labels_next2, mask)


def _apply_single_block(p, cfg, h, positions):
    mixer = "mla" if cfg.use_mla else "attn"
    ffn = "dense" if cfg.d_ff else "none"
    return _block_apply(p, cfg, h, mixer, ffn, positions=positions,
                        window=cfg.attn_window)


# ---------------------------------------------------------------------------
# Top-level entry points
# ---------------------------------------------------------------------------


def lm_train_loss(params, cfg, batch, *, remat=True):
    """batch: {tokens (B,S), labels (B,S), [mask], [prefix_embeds]}.
    Returns (loss, metrics)."""
    tokens = batch["tokens"]
    h = embed_inputs(params, cfg, tokens, batch.get("prefix_embeds"))
    positions = jnp.arange(h.shape[1])
    h, _, aux = lm_hidden(params, cfg, h, positions=positions,
                          window=cfg.attn_window, remat=remat)
    labels = batch["labels"]
    mask = batch.get("mask")
    npfx = h.shape[1] - tokens.shape[1]
    if npfx > 0:                       # VLM prefix: no LM loss on patches
        h = h[:, npfx:]
    ce = chunked_ce_loss(params, cfg, h, labels, mask)
    loss = ce + aux
    metrics = {"loss": loss, "ce": ce, "aux": aux}
    if cfg.mtp_depth > 0:
        shifted = jnp.roll(batch["labels"], -1, axis=1)
        m = mtp_loss(params, cfg, h, batch["labels"], shifted)
        loss = loss + 0.3 * m
        metrics["mtp"] = m
        metrics["loss"] = loss
    return loss, metrics


def lm_prefill(params, cfg, batch, caches, *, window=None, last_pos=None):
    """Prefill: fill KV caches for the prompt, return last-position logits.

    ``last_pos`` — optional (B,) int32 of each sequence's final *prompt*
    position; logits are read there instead of at the padded batch end.
    With right-padded heterogeneous prompts and causal attention the
    logits at ``last_pos[i]`` are exactly the unpadded sequence's next-
    token distribution (later pad positions cannot leak backwards);
    sampling at the shared padded end would condition shorter prompts on
    their own padding.
    """
    tokens = batch.get("tokens")
    h = embed_inputs(params, cfg, tokens, batch.get("prefix_embeds"))
    positions = jnp.arange(h.shape[1])
    h, caches, _ = lm_hidden(params, cfg, h, positions=positions,
                             window=window, caches=caches, cache_pos=0)
    if last_pos is None:
        sel = h[:, -1:]
    else:
        sel = h[jnp.arange(h.shape[0]), jnp.asarray(last_pos)][:, None]
    logits = lm_logits(params, cfg, sel)
    return logits[:, 0], caches


def lm_prefill_slot(params, cfg, batch, caches, slot, *, window=None,
                    last_pos=None):
    """Prefill ONE slot of a slotted cache tree; others untouched.

    ``batch`` holds a single request (leading axis 1); its prompt KV /
    SSM state is computed against a **zeroed** width-1 cache — stale
    conv/SSM state from the slot's previous tenant must not seed the
    new recurrence — and written back into slot ``slot``.  Returns
    ``(logits (1, V), updated full caches)``.  ``slot`` may be traced,
    so one jit covers every slot; retraces happen only per distinct
    prompt length (bucket prompts to bound them).
    """
    sub = jax.tree.map(jnp.zeros_like, cache_slot(caches, slot))
    logits, sub = lm_prefill(params, cfg, batch, sub, window=window,
                             last_pos=last_pos)
    return logits, write_cache_slot(caches, sub, slot)


def lm_decode_step(params, cfg, token, caches, pos, *, window=None):
    """One decode step.  token: (B,1) int32; pos: scalar int32 (lockstep
    batch — every row reads/writes the same cache position) or (B,)
    int32 (continuous batching — row i writes at ``pos[i]`` and attends
    only ``[0, pos[i]]``, so a shorter request's continuation can never
    see pad KV or a reused slot's stale entries).
    Returns (logits (B,V), new caches)."""
    h = embed_inputs(params, cfg, token)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        positions = pos + jnp.arange(1)
    else:
        positions = pos[:, None]                           # (B, 1) per-row
    h, caches, _ = lm_hidden(params, cfg, h, positions=positions,
                             window=window, caches=caches, cache_pos=pos)
    logits = lm_logits(params, cfg, h)
    return logits[:, 0], caches
