"""Mamba-2 mixer via the SSD (state-space duality) chunked algorithm.

arXiv:2405.21060.  The TPU adaptation (DESIGN.md §3): instead of the
GPU-oriented parallel-scan with warp shuffles, training/prefill use the
*chunked* SSD form — within-chunk work is a masked-decay quadratic form
(dense matmuls on the MXU), across-chunk work is a tiny ``lax.scan`` over
(H, P, N) states.  Decode is the O(1)-per-token recurrence.

Shapes: d_inner = expand·d_model, H = d_inner/P heads, G groups for B/C,
N state dim.  Cache = {"conv": (B, W-1, d_conv_ch), "ssm": (B, H, P, N)}.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def mamba_init(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.num_heads(d)
    gn = s.num_groups * s.d_state
    conv_ch = di + 2 * gn
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4)

    # dt bias: softplus^-1 of dt ~ logU[1e-3, 0.1]  (mamba2 reference init)
    u = jax.random.uniform(keys[2], (H,), jnp.float32)
    dt0 = jnp.exp(u * (np.log(0.1) - np.log(1e-3)) + np.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))

    return {
        "in_proj": L.dense_init(keys[0], d, 2 * di + 2 * gn + H, dtype=cfg.param_dtype),
        "conv_w": (jax.random.normal(keys[1], (s.conv_width, conv_ch), jnp.float32)
                   / np.sqrt(s.conv_width)).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias,
        "gated_norm": L.rmsnorm_init(di, dtype=cfg.param_dtype),
        "out_proj": L.dense_init(keys[3], di, d, dtype=cfg.param_dtype),
    }


def init_mamba_cache(cfg, batch: int, dtype=None):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.num_heads(d)
    gn = s.num_groups * s.d_state
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, di + 2 * gn), dtype),
        "ssm": jnp.zeros((batch, H, s.head_dim, s.d_state), jnp.float32),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv via shifted adds (width is tiny)."""
    W = w.shape[0]
    out = u * w[-1].astype(u.dtype)
    for i in range(1, W):
        shifted = jnp.pad(u[:, :-i], ((0, 0), (i, 0), (0, 0)))
        out = out + shifted * w[W - 1 - i].astype(u.dtype)
    return out + b.astype(u.dtype)


def _split_in_proj(p, x, cfg):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    gn = s.num_groups * s.d_state
    zxbcdt = L.dense(p["in_proj"], x)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * gn]
    dt_raw = zxbcdt[..., 2 * di + 2 * gn:]
    return z, xbc, dt_raw


def _ssd_chunked(xh, dt, A, Bm, Cm, cfg, h0):
    """Chunked SSD scan.

    xh (b,s,H,P), dt (b,s,H) post-softplus, A (H,) negative,
    Bm/Cm (b,s,G,N).  Returns (y (b,s,H,P), h_final (b,H,P,N)).
    """
    s_cfg = cfg.ssm
    b, S, H, P = xh.shape
    G = s_cfg.num_groups
    R = H // G
    Q = min(s_cfg.chunk_size, S)
    pad = (-S) % Q
    if pad:
        pz = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        # dt must pad with ZEROS post-softplus semantics: a padded step must
        # neither decay the carried state (exp(dt*A)=1) nor inject input
        # (dt*B*x=0), otherwise the final state handed to decode is wrong.
        xh, dt, Bm, Cm = pz(xh), pz(dt), pz(Bm), pz(Cm)
    Sp = S + pad
    c = Sp // Q

    f32 = jnp.float32
    xdt = xh * dt[..., None]                                  # (b,Sp,H,P)
    dA = (dt * A).reshape(b, c, Q, H).astype(f32)             # negative
    cs = jnp.cumsum(dA, axis=2)                               # (b,c,Q,H)

    def grp(t):  # (b,Sp,H,...) -> (b,c,Q,G,R,...)
        return t.reshape(b, c, Q, G, R, *t.shape[3:])

    x_g = grp(xdt)                                            # (b,c,Q,G,R,P)
    cs_g = cs.reshape(b, c, Q, G, R)
    Bc = Bm.reshape(b, c, Q, G, s_cfg.d_state)
    Cc = Cm.reshape(b, c, Q, G, s_cfg.d_state)
    mask = jnp.tril(jnp.ones((Q, Q), bool))[:, None, None, :]

    # §Perf H7: intra-chunk work runs INSIDE the chunk scan — the masked
    # decay tensor (Q,G,R,Q) and its einsums exist for one chunk at a time
    # (before-state materialized (b,c,Q,G,R,Q) across all chunks at once:
    # ~34 GiB/dev on jamba prefill_32k).  This mirrors the per-chunk grid
    # of the Pallas kernel (kernels/ssd_pallas.py).
    def step(h, inp):
        xg, csg, bc, cc = inp          # (b,Q,G,R,P) (b,Q,G,R) (b,Q,G,N) x2
        xg = xg.astype(f32)
        csg = csg.astype(f32)
        att = jnp.einsum("bqgn,blgn->bgql", cc.astype(f32), bc.astype(f32))
        diff = csg[:, :, :, :, None] - jnp.moveaxis(
            csg, 1, -1)[:, None, :, :, :]                      # (b,q,g,r,l)
        ldec = jnp.where(mask[None], jnp.exp(diff), 0.0)
        m = jnp.einsum("bgql,bqgrl->bqgrl", att, ldec)
        y_diag = jnp.einsum("bqgrl,blgrp->bqgrp", m, xg)

        decay_last = jnp.exp(csg[:, -1:] - csg)                # (b,Q,G,R)
        state = jnp.einsum("bqgn,bqgr,bqgrp->bgrpn",
                           bc.astype(f32), decay_last, xg)
        y_off = jnp.einsum("bqgn,bgrpn,bqgr->bqgrp",
                           cc.astype(f32), h, jnp.exp(csg))
        chunk_decay = jnp.exp(csg[:, -1])                      # (b,G,R)
        h_next = h * chunk_decay[..., None, None] + state
        return h_next, (y_diag + y_off)

    if h0 is None:
        h0 = jnp.zeros((b, G, R, P, s_cfg.d_state), f32)
    h_final, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(x_g, 1, 0), jnp.moveaxis(cs_g, 1, 0),
         jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0)))

    y = jnp.moveaxis(ys, 0, 1).reshape(b, Sp, H, P)
    if pad:
        y = y[:, :S]
    return y, h_final.reshape(b, H, P, s_cfg.d_state)


def mamba_apply(p, x, cfg, *, cache=None):
    """Mamba2 mixer.  x: (B,S,d) -> (out, new_cache)."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.num_heads(d)
    P = s.head_dim
    G, N = s.num_groups, s.d_state
    cdt = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cdt)
    B_, S, _ = x.shape

    z, xbc_pre, dt_raw = _split_in_proj(p, x, cfg)
    A = -jnp.exp(p["A_log"])                                   # (H,)

    if cache is None or S > 1:
        if cache is not None:
            # continuation: the causal conv needs the previous W-1 inputs
            tail = cache["conv"].astype(xbc_pre.dtype)
            xbc_in = jnp.concatenate([tail, xbc_pre], axis=1)
            xbc = jax.nn.silu(_causal_conv(xbc_in, p["conv_w"],
                                           p["conv_b"]))[:, tail.shape[1]:]
        else:
            xbc = jax.nn.silu(_causal_conv(xbc_pre, p["conv_w"], p["conv_b"]))
        xh = xbc[..., :di].reshape(B_, S, H, P)
        Bm = xbc[..., di: di + G * N].reshape(B_, S, G, N)
        Cm = xbc[..., di + G * N:].reshape(B_, S, G, N)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"])                   # (B,S,H)
        h0 = None
        if cache is not None:
            h0 = cache["ssm"].reshape(B_, G, H // G, P, N)
        y, h_fin = _ssd_chunked(xh, dt, A, Bm, Cm, cfg, h0)
        new_cache = None
        if cache is not None:
            tail = s.conv_width - 1
            conv_tail = xbc_pre[:, -tail:] if S >= tail else jnp.concatenate(
                [cache["conv"][:, S:], xbc_pre], axis=1)
            new_cache = {"conv": conv_tail.astype(cache["conv"].dtype),
                         "ssm": h_fin}
    else:
        # ---- single-token recurrent decode ---------------------------------
        window = jnp.concatenate(
            [cache["conv"].astype(cdt), xbc_pre], axis=1)      # (B,W,ch)
        xbc = jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(cdt))
        xbc = jax.nn.silu(xbc + p["conv_b"].astype(cdt))
        xh = xbc[:, :di].reshape(B_, H, P)
        Bm = xbc[:, di: di + G * N].reshape(B_, G, N)
        Cm = xbc[:, di + G * N:].reshape(B_, G, N)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                             + p["dt_bias"])                   # (B,H)
        h = cache["ssm"]                                       # (B,H,P,N) f32
        decay = jnp.exp(dt * A)                                # (B,H)
        Bh = jnp.repeat(Bm, H // G, axis=1)                    # (B,H,N)
        Ch = jnp.repeat(Cm, H // G, axis=1)
        upd = (dt[..., None] * xh).astype(jnp.float32)         # (B,H,P)
        h = h * decay[..., None, None] + upd[..., None] * Bh[:, :, None, :].astype(jnp.float32)
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(jnp.float32))
        y = y.reshape(B_, 1, H, P)
        new_cache = {"conv": window[:, 1:].astype(cache["conv"].dtype),
                     "ssm": h}

    xh_full = (xbc[..., :di].reshape(B_, S, H, P) if (cache is None or S > 1)
               else xh.reshape(B_, 1, H, P))
    y = y + p["D"][None, None, :, None] * xh_full.astype(y.dtype)
    y = y.reshape(B_, S, di).astype(cdt)
    y = L.rmsnorm(p["gated_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return L.dense(p["out_proj"], y), new_cache
