"""Grouped-query attention with KV-cache decode, qk-norm, sliding window.

Covers every attention variant in the assigned pool except DeepSeek MLA
(see ``mla.py``): GQA (Llama/Qwen/Jamba), MQA (Gemma-2B kv=1), qk-norm
(Qwen3), QKV bias (Qwen2), sliding-window masking (used for the long_500k
decode shape on full-attention archs), and bidirectional/cross attention
for the encoder-decoder (Seamless) family.

Modes
-----
* full   : (B, S, d) -> (B, S, d), causal (or bidirectional) mask.
* decode : (B, 1, d) + cache {k,v: (B, S_max, K, hd)} -> one-step output
           and the updated cache.  ``cache_pos`` is the write position —
           a scalar (lockstep batch: every row writes at the same
           position) or a per-request ``(B,)`` vector (continuous
           batching: row i writes at ``cache_pos[i]`` and its causal
           mask confines reads to ``[0, cache_pos[i]]``, so pad or
           stale slot entries can never leak into another request's
           continuation).

The pure-jnp path below is the oracle; ``kernels/flash_attention_pallas.py``
provides the TPU Pallas kernel validated against it (flip with
``use_pallas``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.sharding import constrain

NEG_INF = -1e30


def attn_init(key, cfg, *, cross: bool = False):
    keys = jax.random.split(key, 6)
    dt = cfg.param_dtype
    d = cfg.d_model
    p = {
        "wq": L.dense_init(keys[0], d, cfg.q_dim, bias=cfg.qkv_bias, dtype=dt),
        "wk": L.dense_init(keys[1], d, cfg.kv_dim, bias=cfg.qkv_bias, dtype=dt),
        "wv": L.dense_init(keys[2], d, cfg.kv_dim, bias=cfg.qkv_bias, dtype=dt),
        "wo": L.dense_init(keys[3], cfg.q_dim, d, dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(cfg.head_dim, dtype=dt)
        p["k_norm"] = L.rmsnorm_init(cfg.head_dim, dtype=dt)
    del cross  # same parameter shapes; kept for call-site clarity
    return p


def init_kv_cache(cfg, batch: int, max_seq: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    shape = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _qk_normalize(p, q, k, eps):
    if "q_norm" in p:
        q = L.rmsnorm(p["q_norm"], q, eps)
        k = L.rmsnorm(p["k_norm"], k, eps)
    return q, k


def _gqa_scores(q, k):
    """(B,S,H,hd) x (B,T,K,hd) -> (B,K,H/K,S,T) grouped scores."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    q = q.reshape(B, S, K, H // K, hd)
    return jnp.einsum("bskgd,btkd->bkgst", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(w, v):
    """(B,K,H/K,S,T) x (B,T,K,hd) -> (B,S,H,hd)."""
    B, K, G, S, T = w.shape
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out.reshape(B, S, K * G, v.shape[-1])


def make_mask(q_positions, k_positions, *, causal: bool, window=None,
              k_valid_len=None):
    """Boolean mask (broadcastable to (..., S_q, S_k)); True = attend."""
    qp = q_positions[..., :, None]
    kp = k_positions[..., None, :]
    mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    if k_valid_len is not None:
        mask &= kp < k_valid_len
    return mask


def blocked_attention(q, k, v, *, causal=True, window=None, softcap=None,
                      q_positions=None, k_positions=None, k_valid_len=None,
                      scale=None, q_chunk=256, kv_chunk=512):
    """Flash-style online-softmax attention in pure jnp.

    Memory per step is O(q_chunk * kv_chunk) instead of O(S_q * S_k), which
    is what lets the 32k-prefill shapes lower with bounded activations.  The
    Pallas TPU kernel (`kernels/flash_attention_pallas.py`) implements the
    same schedule with VMEM BlockSpecs; this function is its jnp twin and
    the production fallback path.

    q: (B, Sq, H, d); k/v: (B, T, K, dv) with H = K * G (GQA).
    Returns (B, Sq, H, dv).
    """
    B, Sq, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    dv = v.shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(dh)
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if k_positions is None:
        k_positions = jnp.arange(T)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, T)
    pq = (-Sq) % q_chunk
    pk = (-T) % kv_chunk
    qp = jnp.pad(q_positions, (0, pq), constant_values=-1)
    kp = jnp.pad(k_positions, (0, pk), constant_values=2**30)
    qq = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kk = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vv = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (Sq + pq) // q_chunk, (T + pk) // kv_chunk

    # keep the staged tensors in their input dtype (bf16 in production) —
    # per-chunk math below upcasts to f32.  Staging everything in f32 was
    # §Perf iteration H1's before-state: it doubled peak prefill bytes.
    qq = qq.reshape(B, nq, q_chunk, K, G, dh)
    kk = kk.reshape(B, nk, kv_chunk, K, dh)
    vv = vv.reshape(B, nk, kv_chunk, K, dv)
    qp = qp.reshape(nq, q_chunk)
    kp = kp.reshape(nk, kv_chunk)
    valid_len = k_valid_len if k_valid_len is not None else T

    def q_step(_, q_in):
        qi, qpos = q_in                                    # (B,Qc,K,G,dh),(Qc,)

        def kv_step(carry, kv_in):
            m, l, acc = carry
            kj, vj, kpos = kv_in
            s = jnp.einsum("bqkgd,btkd->bkgqt", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            msk = (kpos[None, :] < valid_len)
            if causal:
                msk = msk & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                msk = msk & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p_ = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p_, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p_.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kk, 1, 0), jnp.moveaxis(vv, 1, 0), kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (B,K,G,Qc,dv)
        return None, jnp.moveaxis(out, 3, 1)               # (B,Qc,K,G,dv)

    _, outs = jax.lax.scan(q_step, None, (jnp.moveaxis(qq, 1, 0), qp))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, (Sq + pq), H, dv)
    return out[:, :Sq].astype(v.dtype)


# sequence length at/above which the full-attention einsum path switches to
# the memory-bounded blocked path.
BLOCKED_ATTN_THRESHOLD = 2048


def attention(p, x, cfg, *, positions, causal=True, window=None,
              memory=None, cross=False, cache=None, cache_pos=None):
    """Unified attention entry point.

    Args:
      p: params from :func:`attn_init`.
      x: (B, S, d) queries' residual stream.
      positions: (S,) or (B, S) absolute positions for RoPE + masking.
      causal / window: mask controls (ignored for cross attention).
      memory: (B, T, d) cross-attention memory (encoder output).
      cross: cross-attention flag; with ``cache`` set and no ``memory``,
        K/V are read from the precomputed cross cache (decode path).
      cache / cache_pos: KV cache; ``cache_pos`` is the write position.

    Returns (out, new_cache) — new_cache is None unless a cache was given.
    """
    B, S, _ = x.shape
    cross = cross or (memory is not None)
    cdt = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cdt)

    q = _split_heads(L.dense(p["wq"], x), cfg.num_heads, cfg.head_dim)
    if cross and memory is None:
        k = v = None                       # served from cross cache below
    else:
        kv_src = x if memory is None else memory.astype(cdt)
        k = _split_heads(L.dense(p["wk"], kv_src), cfg.num_kv_heads,
                         cfg.head_dim)
        v = _split_heads(L.dense(p["wv"], kv_src), cfg.num_kv_heads,
                         cfg.head_dim)
    if k is not None:
        q, k = _qk_normalize(p, q, k, cfg.norm_eps)
    elif "q_norm" in p:
        q = L.rmsnorm(p["q_norm"], q, cfg.norm_eps)

    if not cross:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and not cross:
        per_row = jnp.ndim(cache_pos) == 1
        if per_row and S != 1:
            raise ValueError(
                "per-request cache_pos requires S == 1 (decode); "
                "slot-targeted prefill goes through lm_prefill_slot")
        if per_row:
            # continuous-batching decode: each row writes its token's
            # k/v at its OWN position (S must be 1 — per-row prefill
            # goes through the slot-targeted path in transformer.py)
            rows = jnp.arange(B)
            k_c = cache["k"].at[rows, jnp.asarray(cache_pos)].set(
                k[:, 0].astype(cache["k"].dtype))
            v_c = cache["v"].at[rows, jnp.asarray(cache_pos)].set(
                v[:, 0].astype(cache["v"].dtype))
        else:
            # write this step's (or this prefill block's) k/v into the
            # cache at the shared position.
            k_c = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
            v_c = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
        new_cache = {"k": k_c, "v": v_c}
        k, v = k_c, v_c
        k_positions = jnp.arange(k.shape[1])
        causal = True
        if window is not None and S == 1 and not per_row \
                and k.shape[1] > 2 * window:
            # H3 (§Perf): windowed long-context decode reads only the live
            # window of the cache instead of masking the full 500k entries
            # — cuts executed attention FLOPs and cache HBM reads by
            # seq_len/window (64x at long_500k).
            start = jnp.clip(cache_pos - window + 1, 0,
                             k.shape[1] - window)
            k = jax.lax.dynamic_slice_in_dim(k, start, window, axis=1)
            v = jax.lax.dynamic_slice_in_dim(v, start, window, axis=1)
            k_positions = start + jnp.arange(window)
    elif cache is not None:
        # cross-attention against the precomputed memory cache.
        k, v = cache["k"], cache["v"]
        new_cache = cache
        k_positions = jnp.arange(k.shape[1])
        causal = False
    else:
        k_positions = positions if not cross else jnp.arange(k.shape[1])
        if cross:
            causal = False

    q_pos1d = positions if positions.ndim == 1 else positions[0]
    k_pos1d = k_positions if k_positions.ndim == 1 else k_positions[0]
    # per-request positions: keep the (B, S) shape so every row masks
    # against its own write position (the (1, S) squeeze below would
    # silently share row 0's mask across the batch)
    q_pos2d = positions if positions.ndim == 2 else q_pos1d[None]

    if S >= BLOCKED_ATTN_THRESHOLD:
        out = blocked_attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.logit_softcap, q_positions=q_pos1d,
            k_positions=k_pos1d)
    else:
        mask = make_mask(q_pos2d, k_pos1d[None], causal=causal,
                         window=window if causal else None)
        scores = _gqa_scores(q, k) / np.sqrt(cfg.head_dim)
        if cfg.logit_softcap:
            cap = cfg.logit_softcap
            scores = jnp.tanh(scores / cap) * cap
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(w, v)
    out = constrain(out, ("pod", "data"), None, "model", None)
    out = L.dense(p["wo"], out.reshape(B, S, cfg.q_dim))
    return out, new_cache
