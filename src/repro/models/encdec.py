"""Encoder–decoder transformer for the audio family (Seamless-M4T medium).

Per the assignment carve-out the modality frontend (mel-spectrogram +
conv feature extractor) is a stub: ``input_specs()`` supplies precomputed
frame embeddings of shape (B, T_src, d_model).  This module implements the
transformer backbone: a bidirectional encoder over frame embeddings and a
causal text decoder with cross-attention, including cached decode.

Cache layout for decode:
  {"self":  per-layer stacked KV cache over target positions,
   "cross": per-layer stacked K/V of the encoder memory (precomputed)}
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models.sharding import constrain_batch
from repro.models.transformer import chunked_ce_loss, lm_logits


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _enc_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
        "attn": A.attn_init(k1, cfg),
        "ffn_norm": L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
        "ffn": L.mlp_init(k2, cfg.d_model, cfg.d_ff, act=cfg.mlp_act,
                          dtype=cfg.param_dtype),
    }


def _dec_block_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
        "self_attn": A.attn_init(k1, cfg),
        "cross_norm": L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
        "cross_attn": A.attn_init(k2, cfg, cross=True),
        "ffn_norm": L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
        "ffn": L.mlp_init(k3, cfg.d_model, cfg.d_ff, act=cfg.mlp_act,
                          dtype=cfg.param_dtype),
    }


def init_encdec(key, cfg):
    ke, kd, kt, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(ke, cfg.num_encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    params = {
        "encoder": {
            "blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(enc_keys),
            "norm": L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
        },
        "decoder": {
            "blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
            "norm": L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
        },
        "embed": L.embed_init(kt, cfg.vocab_size, cfg.d_model,
                              dtype=cfg.param_dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(kh, cfg.d_model, cfg.vocab_size,
                                         dtype=cfg.param_dtype)
    return params


def init_encdec_cache(cfg, batch: int, max_seq: int, dtype=None):
    nl = cfg.num_layers
    self_one = A.init_kv_cache(cfg, batch, max_seq, dtype)
    cross_one = A.init_kv_cache(cfg, batch, cfg.encoder_seq_len, dtype)
    stack = lambda c: jax.tree.map(
        lambda x: jnp.broadcast_to(x, (nl, *x.shape)), c)
    return {"self": stack(self_one), "cross": stack(cross_one)}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def encode(params, cfg, src_embeds, *, remat=False):
    """Bidirectional encoder over stub frame embeddings (B, T, d)."""
    h = constrain_batch(src_embeds.astype(jnp.dtype(cfg.compute_dtype)))
    positions = jnp.arange(h.shape[1])

    def body(h, blk):
        hn = L.rmsnorm(blk["attn_norm"], h, cfg.norm_eps)
        out, _ = A.attention(blk["attn"], hn, cfg, positions=positions,
                             causal=False)
        h = h + out.astype(h.dtype)
        hn = L.rmsnorm(blk["ffn_norm"], h, cfg.norm_eps)
        h = h + L.mlp(blk["ffn"], hn, act=cfg.mlp_act).astype(h.dtype)
        return h, None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["encoder"]["blocks"])
    return L.rmsnorm(params["encoder"]["norm"], h, cfg.norm_eps)


def _decoder(params, cfg, h, memory, *, positions, caches=None,
             cache_pos=None, window=None, remat=False):
    """Decoder stack.  ``memory`` may be None when cross caches are given."""

    def body(h, xs):
        blk, self_c, cross_c = xs
        hn = L.rmsnorm(blk["self_norm"], h, cfg.norm_eps)
        out, new_self = A.attention(blk["self_attn"], hn, cfg,
                                    positions=positions, window=window,
                                    cache=self_c, cache_pos=cache_pos)
        h = h + out.astype(h.dtype)
        hn = L.rmsnorm(blk["cross_norm"], h, cfg.norm_eps)
        out, new_cross = A.attention(blk["cross_attn"], hn, cfg,
                                     positions=positions, memory=memory,
                                     cross=True, cache=cross_c)
        h = h + out.astype(h.dtype)
        hn = L.rmsnorm(blk["ffn_norm"], h, cfg.norm_eps)
        h = h + L.mlp(blk["ffn"], hn, act=cfg.mlp_act).astype(h.dtype)
        return h, (new_self, new_cross)

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    xs = (params["decoder"]["blocks"],
          caches["self"] if caches else None,
          caches["cross"] if caches else None)
    h, new_caches = jax.lax.scan(body, h, xs)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if caches is not None:
        new_caches = {"self": new_caches[0], "cross": new_caches[1]}
    else:
        new_caches = None
    return h, new_caches


def build_cross_cache(params, cfg, memory):
    """Precompute per-layer cross-attention K/V from the encoder output."""

    def body(_, blk):
        hn = memory  # cross K/V projections consume raw encoder output
        k = L.dense(blk["cross_attn"]["wk"], hn)
        v = L.dense(blk["cross_attn"]["wv"], hn)
        shape = (*k.shape[:-1], cfg.num_kv_heads, cfg.head_dim)
        return None, {"k": k.reshape(shape), "v": v.reshape(shape)}

    _, cache = jax.lax.scan(body, None, params["decoder"]["blocks"])
    return cache


def encdec_train_loss(params, cfg, batch, *, remat=True):
    """batch: {src_embeds (B,T,d), tokens (B,S), labels (B,S), [mask]}."""
    memory = encode(params, cfg, batch["src_embeds"], remat=remat)
    h = L.embed(params["embed"], batch["tokens"]).astype(
        jnp.dtype(cfg.compute_dtype))
    h = constrain_batch(h)
    positions = jnp.arange(h.shape[1])
    h, _ = _decoder(params, cfg, h, memory, positions=positions, remat=remat)
    ce = chunked_ce_loss(params, cfg, h, batch["labels"], batch.get("mask"))
    return ce, {"loss": ce, "ce": ce}


def encdec_prefill(params, cfg, batch, caches, *, window=None):
    """Encode source, build cross caches, prefill decoder self cache."""
    memory = encode(params, cfg, batch["src_embeds"])
    cross = build_cross_cache(params, cfg, memory)
    caches = {"self": caches["self"], "cross": cross}
    h = L.embed(params["embed"], batch["tokens"]).astype(
        jnp.dtype(cfg.compute_dtype))
    positions = jnp.arange(h.shape[1])
    h, caches = _decoder(params, cfg, h, None, positions=positions,
                         caches=caches, cache_pos=0, window=window)
    return lm_logits(params, cfg, h[:, -1:])[:, 0], caches


def encdec_decode_step(params, cfg, token, caches, pos, *, window=None):
    """One decode step against prefilled self+cross caches."""
    h = L.embed(params["embed"], token).astype(jnp.dtype(cfg.compute_dtype))
    positions = pos + jnp.arange(1)
    h, caches = _decoder(params, cfg, h, None, positions=positions,
                         caches=caches, cache_pos=pos, window=window)
    return lm_logits(params, cfg, h)[:, 0], caches
