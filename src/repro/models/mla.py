"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2412.19437).

Queries and keys/values are projected through low-rank latents; only the
compressed KV latent (``kv_lora_rank`` wide) plus a small shared RoPE key
is cached, cutting decode KV-cache bytes by ~an order of magnitude vs GQA.

Two execution paths:

* **expanded** (train / prefill): latents are up-projected to per-head
  K/V and attention proceeds normally — matmul-rich, MXU friendly.
* **absorbed** (decode): the up-projections are algebraically absorbed
  into the query / output sides, so attention runs directly against the
  compressed cache.  For the ``long_500k`` shape this avoids materializing
  a (B, 500k, H, 256) expanded key tensor — per-step work is O(S ·
  (kv_lora + rope_dim)) instead of O(S · H · qk_dim).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

NEG_INF = -1e30


def mla_init(key, cfg):
    m = cfg.mla
    d = cfg.d_model
    H = cfg.num_heads
    dt = cfg.param_dtype
    keys = jax.random.split(key, 5)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": L.dense_init(keys[0], d, m.q_lora_rank, dtype=dt),
        "q_norm": L.rmsnorm_init(m.q_lora_rank, dtype=dt),
        "wq_b": L.dense_init(keys[1], m.q_lora_rank, H * qk_head, dtype=dt),
        "wkv_a": L.dense_init(keys[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                              dtype=dt),
        "kv_norm": L.rmsnorm_init(m.kv_lora_rank, dtype=dt),
        "wkv_b": L.dense_init(keys[3], m.kv_lora_rank,
                              H * (m.qk_nope_head_dim + m.v_head_dim), dtype=dt),
        "wo_mla": L.dense_init(keys[4], H * m.v_head_dim, d, dtype=dt),
    }


def init_mla_cache(cfg, batch: int, max_seq: int, dtype=None):
    m = cfg.mla
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    return {
        "ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dtype),
    }


def _project_q(p, x, cfg, positions):
    m = cfg.mla
    H = cfg.num_heads
    cq = L.rmsnorm(p["q_norm"], L.dense(p["wq_a"], x), cfg.norm_eps)
    q = L.dense(p["wq_b"], cq)
    q = q.reshape(*q.shape[:-1], H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = L.apply_rope(q[..., m.qk_nope_head_dim:], positions,
                          cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p, x, cfg, positions):
    m = cfg.mla
    ckv_full = L.dense(p["wkv_a"], x)
    ckv = L.rmsnorm(p["kv_norm"], ckv_full[..., : m.kv_lora_rank], cfg.norm_eps)
    k_rope = ckv_full[..., m.kv_lora_rank:]
    # shared-across-heads rope key: add a head axis for apply_rope.
    k_rope = L.apply_rope(k_rope[..., None, :], positions,
                          cfg.rope_theta)[..., 0, :]
    return ckv, k_rope


def _split_wkv_b(p, cfg):
    m = cfg.mla
    H = cfg.num_heads
    w = p["wkv_b"]["w"]                                     # (r, H*(dn+dv))
    w = w.reshape(m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    return w[..., : m.qk_nope_head_dim], w[..., m.qk_nope_head_dim:]


def mla_attention(p, x, cfg, *, positions, window=None, cache=None,
                  cache_pos=None):
    """MLA forward.  Same contract as ``attention.attention``."""
    m = cfg.mla
    B, S, _ = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cdt)
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)

    q_nope, q_rope = _project_q(p, x, cfg, positions)
    ckv, k_rope = _project_kv_latent(p, x, cfg, positions)
    w_uk, w_uv = _split_wkv_b(p, cfg)

    new_cache = None
    if cache is not None:
        per_row = jnp.ndim(cache_pos) == 1
        if per_row and S != 1:
            raise ValueError(
                "per-request cache_pos requires S == 1 (decode); "
                "slot-targeted prefill goes through lm_prefill_slot")
        if per_row:
            # continuous-batching decode: row i writes its latent at its
            # own position; the per-row causal mask below confines reads
            # to [0, cache_pos[i]] so stale slot entries never leak.
            rows = jnp.arange(B)
            ckv_c = cache["ckv"].at[rows, jnp.asarray(cache_pos)].set(
                ckv[:, 0].astype(cache["ckv"].dtype))
            kr_c = cache["krope"].at[rows, jnp.asarray(cache_pos)].set(
                k_rope[:, 0].astype(cache["krope"].dtype))
        else:
            ckv_c = jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_pos,
                axis=1)
            kr_c = jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], k_rope.astype(cache["krope"].dtype),
                cache_pos, axis=1)
        new_cache = {"ckv": ckv_c, "krope": kr_c}
        if window is not None and S == 1 and not per_row \
                and ckv_c.shape[1] > 2 * window:
            # H3 (§Perf): windowed decode against the live cache slice only.
            start = jnp.clip(cache_pos - window + 1, 0,
                             ckv_c.shape[1] - window)
            ckv_used = jax.lax.dynamic_slice_in_dim(ckv_c, start, window, 1)
            kr_used = jax.lax.dynamic_slice_in_dim(kr_c, start, window, 1)
            kp_base = start
            kv_len = window
        else:
            ckv_used, kr_used = ckv_c, kr_c
            kp_base = 0
            kv_len = ckv_c.shape[1]

    if cache is not None and S == 1:
        # --- absorbed decode ------------------------------------------------
        # scores = q_nope · (W_uk c) + q_rope · k_rope
        q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk.astype(cdt))
        s_nope = jnp.einsum("bshr,btr->bhst", q_abs, ckv_used.astype(cdt),
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bshd,btd->bhst", q_rope, kr_used.astype(cdt),
                            preferred_element_type=jnp.float32)
        scores = (s_nope + s_rope) * scale
        kp = (kp_base + jnp.arange(kv_len))[None]
        qp = positions[None] if positions.ndim == 1 else positions
        mask = (kp[:, None, :] <= qp[..., None])
        if window is not None:
            mask &= kp[:, None, :] > qp[..., None] - window
        scores = jnp.where(mask[:, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", w.astype(cdt),
                           ckv_used.astype(cdt))
        out = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv.astype(cdt))
    else:
        # --- expanded train / prefill ----------------------------------------
        k_nope = jnp.einsum("btr,rhd->bthd", ckv, w_uk.astype(cdt))
        v = jnp.einsum("btr,rhd->bthd", ckv, w_uv.astype(cdt))
        k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                    (*k_rope.shape[:2], cfg.num_heads,
                                     m.qk_rope_head_dim))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
        from repro.models.attention import (BLOCKED_ATTN_THRESHOLD,
                                            blocked_attention)
        if S >= BLOCKED_ATTN_THRESHOLD:
            qpos = positions if positions.ndim == 1 else positions[0]
            out = blocked_attention(q, k, v, causal=True, window=window,
                                    q_positions=qpos, k_positions=qpos,
                                    scale=scale)
        else:
            scores = jnp.einsum("bshd,bthd->bhst", q, k,
                                preferred_element_type=jnp.float32) * scale
            qp = positions[None] if positions.ndim == 1 else positions
            kp = qp
            mask = kp[:, None, :] <= qp[..., None]
            if window is not None:
                mask &= kp[:, None, :] > qp[..., None] - window
            scores = jnp.where(mask[:, None], scores, NEG_INF)
            w = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bhst,bthd->bshd", w.astype(cdt), v)

    out = out.reshape(B, S, cfg.num_heads * m.v_head_dim)
    return L.dense(p["wo_mla"], out), new_cache
