"""Mixture-of-Experts FFN with sort-based token-choice dispatch.

Router semantics follow the assigned configs (token-choice top-k with
renormalized gates; DeepSeek-V3-style shared experts supported).  Dispatch
is the sort-based (MegaBlocks-style) formulation rather than the GShard
one-hot einsum: a (tokens·k, E, C) one-hot dispatch tensor for E=256 would
be ~terabytes at the assigned shapes, while the sort-based path peaks at
``capacity_factor ×`` the expanded token activations:

  1. flatten top-k assignments, sort by expert id (XLA sort),
  2. compute each row's rank within its expert from the sorted ids,
  3. scatter rows into an (E, C, d) buffer (rows past capacity C drop),
  4. grouped matmul (E,C,d)x(E,d,ff) — MXU-friendly batched GEMM,
     sharded over the ``model`` axis in the expert dimension (expert
     parallelism; GSPMD inserts the all-to-all),
  5. gather back, unsort, weight by gate probs, sum the k copies.

Auxiliary losses: switch-style load-balance loss and router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.sharding import constrain


def moe_init(key, cfg):
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 5)

    def ew(k, a, b):
        return (jax.random.normal(k, (E, a, b), jnp.float32)
                / jnp.sqrt(a)).astype(dt)

    p = {
        "router": {"w": (jax.random.normal(keys[0], (d, E), jnp.float32)
                         * 0.02).astype(jnp.float32)},
        "experts": {
            "gate": ew(keys[1], d, ff),
            "up": ew(keys[2], d, ff),
            "down": ew(keys[3], ff, d),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = L.mlp_init(keys[4], d, ff * cfg.num_shared_experts,
                                 act=cfg.mlp_act, dtype=cfg.param_dtype)
    return p


def _capacity(num_tokens: int, cfg) -> int:
    """Expert capacity C.  Small batches (decode steps) get the lossless
    C = T*k: the buffer is tiny there and token-dropping would make decode
    logits diverge from the training-time forward pass."""
    expanded = num_tokens * cfg.experts_per_token
    if expanded <= 4096:
        return expanded
    cap = int(expanded * cfg.capacity_factor / cfg.num_experts)
    return max(min(cap, expanded), 1)


def _moe_shard(p, xf, cfg):
    """Dispatch + expert GEMMs + combine for ONE token shard.

    §Perf iteration H5: this runs vmapped over the data shards, so the
    argsort / cumsum / gathers are LOCAL to each shard — the before-state
    sorted globally across all tokens, which forced GSPMD to all-gather
    the full token tensor (478 GiB/dev temp on deepseek prefill).  The
    only cross-device traffic left is the buf/y resharding around the
    expert GEMMs (the canonical expert-parallel all-to-all).
    """
    T, d = xf.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    cdt = xf.dtype

    logits = (xf.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    top_p, top_i = jax.lax.top_k(probs, k)                        # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)        # renorm

    # ---- aux losses (computed without (T,E,k) one-hots) -------------------
    counts = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    frac_tokens = counts / (T * k)
    mean_prob = jnp.mean(probs, axis=0)
    aux_loss = E * jnp.sum(frac_tokens * mean_prob)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- sort-based dispatch (shard-local) --------------------------------
    C = _capacity(T, cfg)
    flat_e = top_i.reshape(-1)                                    # (T*k,)
    flat_p = top_p.reshape(-1).astype(cdt)
    order = jnp.argsort(flat_e)                                   # stable
    sorted_e = flat_e[order]
    token_of = order // k
    # rank of each row within its expert group
    starts = jnp.cumsum(counts.astype(jnp.int32)) - counts.astype(jnp.int32)
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)        # E*C = drop

    x_sorted = jnp.take(xf, token_of, axis=0)                     # (T*k, d)
    buf = jnp.zeros((E * C + 1, d), cdt).at[slot].set(
        jnp.where(keep[:, None], x_sorted, 0))
    buf = buf[:-1].reshape(E, C, d)

    # ---- grouped expert GEMMs (weights EP-sharded: E on data, ff on
    # model — GSPMD inserts the token all-to-all here) ----------------------
    we = p["experts"]
    if cfg.mlp_act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", buf, we["gate"].astype(cdt)))
        h = h * jnp.einsum("ecd,edf->ecf", buf, we["up"].astype(cdt))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, we["up"].astype(cdt)))
    y = jnp.einsum("ecf,efd->ecd", h, we["down"].astype(cdt))

    # ---- combine ------------------------------------------------------------
    y_flat = jnp.concatenate([y.reshape(E * C, d),
                              jnp.zeros((1, d), cdt)], axis=0)
    out_sorted = jnp.take(y_flat, jnp.where(keep, slot, E * C), axis=0)
    inv = jnp.argsort(order)
    out_rows = jnp.take(out_sorted, inv, axis=0) * flat_p[:, None]
    out = out_rows.reshape(T, k, d).sum(axis=1)

    metrics = {
        "moe_aux_loss": aux_loss,
        "moe_z_loss": z_loss,
        "moe_dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out, metrics


def _num_data_shards(total_tokens: int) -> int:
    """§Perf H9: always 1 — the vmapped per-shard dispatch (H5) was
    measured against chunked global dispatch (H6) once the EP layout (H2)
    landed, and LOST on every shape that mattered (llama4 train temp
    27.0 -> 8.0 GiB/dev, deepseek prefill 18.2 -> 15.9): GSPMD mis-shards
    the batched gather inside vmap ('involuntary full rematerialization').
    The chunk scan already bounds live dispatch bytes, and the global
    argsort stays collective-free because chunks are batch-aligned.
    Kept as a function (and documented) so the experiment is reproducible
    by returning the data-axis size here."""
    del total_tokens
    return 1


# §Perf H6: cap the live dispatch working set.  Shards whose token count
# exceeds this are processed by a lax.scan over token chunks, bounding the
# (E·C·d) buffer + sorted-row tensors to ~1-3 GiB regardless of prefill
# length (before-state: 1M-token prefill held ~40 GiB of dispatch tensors
# live per layer).
_DISPATCH_CHUNK = 8192


def _moe_shard_chunked(p, xf, cfg):
    T, d = xf.shape
    if T <= _DISPATCH_CHUNK or T % _DISPATCH_CHUNK:
        return _moe_shard(p, xf, cfg)
    n = T // _DISPATCH_CHUNK
    xs = xf.reshape(n, _DISPATCH_CHUNK, d)

    def body(_, xc):
        return None, _moe_shard(p, xc, cfg)

    _, (out, metrics) = jax.lax.scan(body, None, xs)
    return out.reshape(T, d), jax.tree.map(jnp.mean, metrics)


def moe_apply(p, x, cfg):
    """x: (B, S, d) -> (out (B, S, d), metrics dict with aux losses)."""
    B, S, d = x.shape
    T = B * S
    cdt = x.dtype
    xf = x.reshape(T, d)

    dp = _num_data_shards(T)
    if dp > 1:
        xs = constrain(xf.reshape(dp, T // dp, d), ("pod", "data"), None,
                       None)
        out, metrics = jax.vmap(
            lambda xx: _moe_shard_chunked(p, xx, cfg))(xs)
        out = constrain(out, ("pod", "data"), None, None)
        out = out.reshape(T, d)
        metrics = jax.tree.map(jnp.mean, metrics)
    else:
        out, metrics = _moe_shard_chunked(p, xf, cfg)

    if "shared" in p:
        out = out + L.mlp(p["shared"], xf, act=cfg.mlp_act)
    return out.reshape(B, S, d), metrics
