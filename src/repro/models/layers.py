"""Primitive layers: linear, norms, rotary embeddings, gated MLPs.

All layers are functional: ``*_init(key, ...) -> params`` and a pure apply
function.  Params are plain dicts of jnp arrays so they stack cleanly along
a leading layer dimension for ``lax.scan`` and so the sharding rule engine
(`models/sharding.py`) can address them by path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name):
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, *, bias: bool = False,
               dtype="bfloat16", scale: float | None = None):
    wkey, _ = jax.random.split(key)
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    p = {"w": (jax.random.normal(wkey, (in_dim, out_dim), jnp.float32) * scale
               ).astype(_dtype(dtype))}
    if bias:
        p["b"] = jnp.zeros((out_dim,), _dtype(dtype))
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim: int, dtype="bfloat16"):
    return {"scale": jnp.ones((dim,), _dtype(dtype))}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(dim: int, dtype="bfloat16"):
    return {"scale": jnp.ones((dim,), _dtype(dtype)),
            "bias": jnp.zeros((dim,), _dtype(dtype))}


def layernorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """Rotate ``x`` of shape (..., seq, heads, head_dim) by ``positions``.

    ``positions``: int array broadcastable to x.shape[:-2] + (seq,).
    Uses the split-half convention (GPT-NeoX / Llama).
    """
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                     # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., s, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLPs
# ---------------------------------------------------------------------------

_ACTS = {
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
}


def mlp_init(key, d_model: int, d_ff: int, act: str = "swiglu",
             dtype="bfloat16"):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": dense_init(k1, d_model, d_ff, dtype=dtype),
         "down": dense_init(k2, d_ff, d_model, dtype=dtype)}
    if act in ("swiglu", "geglu"):
        p["gate"] = dense_init(k3, d_model, d_ff, dtype=dtype)
    return p


def mlp(p, x, act: str = "swiglu"):
    if act == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    elif act == "geglu":
        h = jax.nn.gelu(dense(p["gate"], x)) * dense(p["up"], x)
    else:
        h = _ACTS[act](dense(p["up"], x))
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d_model: int, dtype="bfloat16"):
    return {"w": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                  * 0.02).astype(_dtype(dtype))}


def embed(p, tokens):
    return jnp.take(p["w"], tokens, axis=0)


def unembed(p, x):
    """Project activations to logits with the (possibly tied) embedding."""
    return x @ p["w"].astype(x.dtype).T
