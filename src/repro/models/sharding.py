"""Sharding rules: map parameter/activation pytrees to PartitionSpecs.

The framework keeps model code sharding-agnostic.  Distribution is applied
at the jit boundary (``in_shardings`` / ``out_shardings`` computed here) plus
a small number of in-graph ``with_sharding_constraint`` hints, which are
no-ops unless a mesh context has been installed via :func:`use_mesh`.

Conventions (see DESIGN.md §4):

* ``model`` axis: tensor parallelism — attention heads, FFN hidden dim,
  vocab dim of embedding/LM-head, and the expert dim of MoE tensors.
* ``data`` axis: batch data-parallelism and FSDP (ZeRO-3) sharding of
  parameters/optimizer state along a non-model dimension when divisible.
* ``pod`` axis (multi-pod mesh only): pure data parallelism across pods.

Rules are *divisibility-checked*: a dimension is only sharded if it divides
evenly by the axis size; otherwise the rule falls back to replication for
that dim.  This is what lets one rule engine serve 10 architectures.
"""

from __future__ import annotations

import re
from contextlib import contextmanager
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Mesh context
# ---------------------------------------------------------------------------

_ACTIVE_MESH: Optional[Mesh] = None


@contextmanager
def use_mesh(mesh: Mesh):
    """Install ``mesh`` so in-graph constraints become active."""
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _ACTIVE_MESH = prev


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def data_axes(mesh: Mesh):
    """Axes used for batch data parallelism: ('pod','data') or ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain(x, *spec):
    """``with_sharding_constraint`` if a mesh context is active, else id.

    ``spec`` entries may be None, an axis name, or a tuple of axis names.
    Axis names absent from the active mesh are dropped (so the same model
    code runs on single-pod and multi-pod meshes).
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x

    def fix(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in mesh.axis_names else None
        ent = tuple(a for a in entry if a in mesh.axis_names)
        return ent if ent else None

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*[fix(e) for e in spec])))


def constrain_batch(x, batch_dim: int = 0):
    """Shard the batch dim over (pod, data) when divisible, else replicate."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    axes = data_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if x.shape[batch_dim] % size != 0:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = axes
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

# Each rule: (path regex, spec builder). Specs are given for the *unstacked*
# parameter; a leading scan/stack dimension (layers) is detected by ndim
# mismatch and padded with None on the left.
#
# Dimension tags:  'm' -> model axis, 'f' -> fsdp(data) axis, '.' -> None.
_RULES = [
    # Embedding / LM head: vocab on model, d_model on fsdp.
    (r"(^|/)embed(/w)?$", "mf"),
    (r"(^|/)lm_head(/w)?$", "fm"),
    (r"(^|/)mtp.*proj(/w)?$", "fm"),
    # Attention projections.
    (r"wq(/w)?$", "fm"),
    (r"wk(/w)?$", "fm"),
    (r"wv(/w)?$", "fm"),
    (r"wo(/w)?$", "mf"),
    (r"w(q|k|v)/b$", "m"),
    # MLA projections.
    (r"wq_a(/w)?$", "f."),
    (r"wq_b(/w)?$", ".m"),
    (r"wkv_a(/w)?$", "f."),
    (r"wkv_b(/w)?$", ".m"),
    (r"wo_mla(/w)?$", "mf"),
    # MoE: expert-stacked tensors (E, d, ff) / (E, ff, d).  MUST precede
    # the dense-FFN rules — the generic (gate|up)$ pattern also matches
    # "experts/gate" and silently shadowed this rule until §Perf H11
    # caught it via a failing sharding test (rule order made H2 a no-op).
    # H2/H11: experts shard over the DATA axis (expert parallelism) with
    # the expert-ff dim over MODEL — expert params never FSDP-gather or
    # grad-reduce over data; the token all-to-all replaces weight movement.
    # 'F' spans (pod, data) so multi-pod meshes shard experts 32-way (H8).
    (r"experts/(gate|up)$", "F.m"),
    (r"experts/down$", "Fm."),
    # Dense FFN.
    (r"(gate|up)(/w)?$", "fm"),
    (r"down(/w)?$", "mf"),
    (r"router(/w)?$", "f."),
    (r"shared/(gate|up)(/w)?$", "fm"),
    (r"shared/down(/w)?$", "mf"),
    # Mamba2.
    (r"in_proj(/w)?$", "fm"),
    (r"out_proj(/w)?$", "mf"),
    (r"conv_w$", "..m"),
    (r"conv_b$", "m"),
    (r"(A_log|D|dt_bias)$", "m"),
    # Norm scales and other small vectors: replicate.
    (r".*", None),
]


def _spec_for(path: str, ndim: int, shape, mesh: Mesh) -> P:
    fsdp = "data" if "data" in mesh.axis_names else None
    model = "model" if "model" in mesh.axis_names else None
    axis_size = {a: mesh.shape[a] for a in mesh.axis_names}
    big_fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    for pat, tags in _RULES:
        if re.search(pat, path):
            if tags is None:
                return P()
            spec = []
            for tag in tags:
                if tag == "m":
                    spec.append(model)
                elif tag == "f":
                    spec.append(fsdp)
                elif tag == "F":
                    spec.append(big_fsdp if big_fsdp else None)
                else:
                    spec.append(None)
            # left-pad for stacked (scan) leading dims
            spec = [None] * (ndim - len(spec)) + spec
            spec = spec[:ndim]
            # divisibility check: drop axes that don't divide
            out = []
            for dim, ax in zip(shape, spec):
                if ax is not None:
                    size = (int(np.prod([axis_size[a] for a in ax]))
                            if isinstance(ax, tuple) else axis_size[ax])
                    if dim % size != 0:
                        # tuple axes degrade to their first component
                        if (isinstance(ax, tuple) and len(ax) > 1
                                and dim % axis_size[ax[-1]] == 0):
                            ax = ax[-1]
                        else:
                            ax = None
                # unwrap 1-tuples: P(("data",)) != P("data") even though the
                # shardings are identical, which broke the expert rule on
                # single-pod (no 'pod' axis) meshes.
                if isinstance(ax, tuple) and len(ax) == 1:
                    ax = ax[0]
                out.append(ax)
            return P(*out)
    return P()


def params_pspecs(params, mesh: Mesh):
    """PartitionSpec pytree mirroring ``params`` (arrays or ShapeDtypeStructs)."""

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}" if path else k) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            seq = [walk(v, f"{path}/{i}") for i, v in enumerate(node)]
            return type(node)(seq)
        return _spec_for(path, node.ndim, node.shape, mesh)

    return walk(params, "")


def params_shardings(params, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), params_pspecs(params, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def batch_pspec(mesh: Mesh, ndim: int, batch_dim: int = 0, batch_size: int = None) -> P:
    axes = data_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    spec = [None] * ndim
    if batch_size is None or batch_size % size == 0:
        spec[batch_dim] = axes
    return P(*spec)


def kv_cache_pspec(mesh: Mesh, *, batch: int, ndim: int, batch_dim: int,
                   seq_dim: int) -> P:
    """KV-cache spec: batch over (pod,data) when divisible; otherwise shard
    the sequence dim over 'data' (flash-decode style) and replicate batch."""
    axes = data_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    spec = [None] * ndim
    if batch % size == 0:
        spec[batch_dim] = axes
    else:
        spec[seq_dim] = "data" if "data" in mesh.axis_names else None
    return P(*spec)
