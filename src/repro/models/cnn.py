"""The paper's CNN classifier (DQRE §4.2, Fig. 4) in pure JAX.

"the DQRE structure uses a torsion [conv] layer with windowing 3x3 with a
descending rate of 24, 18, 12, and 6, and only one random pooling layer …
The fully connected layer also has 1x1 windowing and rates 7 and 8."

The paper under-specifies the topology (DESIGN.md §8.4); we implement the
faithful reading: four 3x3 conv blocks with channel counts 24/18/12/6, one
*stochastic* ("random") 2x2 pooling layer after the second conv, and two
fully-connected layers.  This is the model trained by the federated clients
in the MNIST / Fashion-MNIST / CIFAR-10 experiments.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _conv_init(key, h, w, cin, cout):
    scale = 1.0 / np.sqrt(h * w * cin)
    return {"w": jax.random.normal(key, (h, w, cin, cout), jnp.float32) * scale,
            "b": jnp.zeros((cout,), jnp.float32)}


def _conv(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def stochastic_pool(x, rng=None):
    """2x2 stochastic pooling (Zeiler & Fergus).  Train mode samples one
    activation per window with probability proportional to its (relu'd)
    magnitude; eval mode uses the probability-weighted average."""
    B, H, W, C = x.shape
    Hp, Wp = H // 2, W // 2
    x = x[:, : Hp * 2, : Wp * 2]
    win = x.reshape(B, Hp, 2, Wp, 2, C).transpose(0, 1, 3, 5, 2, 4)
    win = win.reshape(B, Hp, Wp, C, 4)
    pos = jnp.maximum(win, 0.0)
    denom = jnp.sum(pos, axis=-1, keepdims=True)
    probs = jnp.where(denom > 0, pos / jnp.maximum(denom, 1e-9), 0.25)
    if rng is not None:
        g = jax.random.gumbel(rng, win.shape)
        idx = jnp.argmax(jnp.log(jnp.maximum(probs, 1e-9)) + g, axis=-1)
        out = jnp.take_along_axis(win, idx[..., None], axis=-1)[..., 0]
    else:
        out = jnp.sum(probs * win, axis=-1)
    return out


def cnn_init(key, *, in_channels: int = 1, num_classes: int = 10,
             image_size: int = 28):
    keys = jax.random.split(key, 6)
    chans = [in_channels, 24, 18, 12, 6]
    params = {f"conv{i}": _conv_init(keys[i], 3, 3, chans[i], chans[i + 1])
              for i in range(4)}
    # after one 2x2 pool the spatial dims halve once
    feat = (image_size // 2) ** 2 * chans[-1]
    s1, s2 = 1.0 / np.sqrt(feat), 1.0 / np.sqrt(128)
    params["fc1"] = {"w": jax.random.normal(keys[4], (feat, 128)) * s1,
                     "b": jnp.zeros((128,))}
    params["fc2"] = {"w": jax.random.normal(keys[5], (128, num_classes)) * s2,
                     "b": jnp.zeros((num_classes,))}
    return params


def cnn_apply(params, x, *, rng=None):
    """x: (B, H, W, C) float images -> (B, num_classes) logits."""
    h = jax.nn.relu(_conv(params["conv0"], x))
    h = jax.nn.relu(_conv(params["conv1"], h))
    h = stochastic_pool(h, rng)
    h = jax.nn.relu(_conv(params["conv2"], h))
    h = jax.nn.relu(_conv(params["conv3"], h))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["fc2"]["w"] + params["fc2"]["b"]


def cnn_loss(params, batch, rng=None):
    logits = cnn_apply(params, batch["x"], rng=rng)
    labels = batch["y"]
    ce = -jnp.take_along_axis(jax.nn.log_softmax(logits), labels[:, None],
                              axis=-1)[:, 0]
    return jnp.mean(ce), logits
