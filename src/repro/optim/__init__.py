from repro.optim.optimizers import (adam, adamw, sgd, Optimizer,
                                    cosine_schedule, constant_schedule,
                                    linear_warmup_cosine, clip_by_global_norm)

__all__ = ["adam", "adamw", "sgd", "Optimizer", "cosine_schedule",
           "constant_schedule", "linear_warmup_cosine",
           "clip_by_global_norm"]
