"""Optimizers, schedules and gradient transforms (pure-pytree, optax-free).

An ``Optimizer`` is a pair of pure functions:

  init(params)                      -> opt_state
  update(grads, opt_state, params, step) -> (new_params, new_opt_state)

Optimizer state mirrors the parameter pytree, so the sharding rule engine
assigns it the same PartitionSpecs as the parameters (ZeRO-style: state is
sharded wherever the parameter is).  ``state_dtype`` controls the moment
dtype — bf16 moments are what let the 671B config fit a single v5e pod
(DESIGN.md §4, EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.minimum(step / total_steps, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return f


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)
    def f(step):
        warm = lr * (step + 1) / max(warmup, 1)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return f


# ---------------------------------------------------------------------------
# Gradient transforms
# ---------------------------------------------------------------------------


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


def sgd(lr, momentum: float = 0.0, nesterov: bool = False):
    lr_fn = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: p - (lr_t * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_params, state
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                          state["mu"], grads)
        upd = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype),
                           mu, grads) if nesterov else mu
        new_params = jax.tree.map(
            lambda p, u: p - (lr_t * u.astype(jnp.float32)).astype(p.dtype),
            params, upd)
        return new_params, {"mu": mu}

    return Optimizer(init, update)


def _adam_core(lr, b1, b2, eps, weight_decay, state_dtype):
    lr_fn = lr if callable(lr) else constant_schedule(lr)
    sdt = jnp.dtype(state_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, sdt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        c1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
        c2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m_new / c1
            vhat = v_new / c2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr_t * delta
            return p_new.astype(p.dtype), m_new.astype(sdt), v_new.astype(sdt)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_state = {"m": tdef.unflatten([o[1] for o in out]),
                     "v": tdef.unflatten([o[2] for o in out])}
        return new_params, new_state

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         state_dtype="float32"):
    return _adam_core(lr, b1, b2, eps, 0.0, state_dtype)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, state_dtype="float32"):
    return _adam_core(lr, b1, b2, eps, weight_decay, state_dtype)
