"""Roofline-term extraction from AOT-compiled artifacts (no hardware).

Three terms per (arch × shape × mesh), all in seconds (see the assignment
spec):

  compute    = HLO_FLOPs / (chips × peak)          peak = 197 TFLOP/s bf16
  memory     = HLO_bytes / (chips × HBM_bw)        HBM  = 819 GB/s
  collective = coll_bytes / (chips × link_bw)      ICI  ≈ 50 GB/s/link

``cost_analysis()`` on the SPMD-partitioned module is already *per
device*, so its FLOPs/bytes divide by nothing; collective bytes are parsed
from the optimized HLO text (summing output-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute)
— cost_analysis does not expose them.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

# TPU v5e (target hardware; constants from the assignment)
@dataclasses.dataclass(frozen=True)
class _HW:
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link
    hbm_bytes: float = 16 * 2**30     # v5e HBM capacity


HW = _HW()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one HLO instruction result: "%name = <shape-or-tuple> <opcode>("
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved by each collective kind (output-shape sizes).

    ``-done`` ops are skipped so async pairs aren't double counted.
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shape_text, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_text)
        counts[kind] += 1
    out["total_bytes"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts  # type: ignore[assignment]
    return out


def extract_cost(compiled) -> Dict[str, float]:
    """FLOPs / bytes from ``compiled.cost_analysis()`` (per-device)."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {"flops": 0.0, "bytes_accessed": 0.0}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0) or 0.0)
    byts = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0))
                 or 0.0)
    out = {"flops": flops, "bytes_accessed": byts}
    # keep any per-space byte counters XLA exposes (operand/output spaces)
    for k, v in ca.items():
        if isinstance(v, (int, float)) and k.startswith("bytes accessed"):
            out[k.replace(" ", "_")] = float(v)
    return out


def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs: 6·N·D train, 2·N·D prefill, 2·N·B decode
    (N = active params for MoE)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


_SUGGESTIONS = {
    "compute": ("compute-bound: raise MXU utilization — larger per-device "
                "microbatch, fewer recompute passes (remat policy), or "
                "causal block-skip in attention to cut masked FLOPs"),
    "memory": ("memory-bound: cut HBM traffic — fuse/flash the attention "
               "path, keep weights resident (bigger batch per weight load), "
               "lower-precision cache/activations"),
    "collective": ("collective-bound: reshard to shrink cross-device bytes "
                   "— move FSDP gathers off the critical path, overlap "
                   "collectives with compute, or trade all-gather for "
                   "reduce-scatter schedules"),
}


def roofline_report(cfg, shape, mesh, rec: dict) -> dict:
    cost = rec.get("cost", {})
    coll = rec.get("collectives", {})
    chips = mesh.size
    compute_s = cost.get("flops", 0.0) / HW.peak_flops
    memory_s = cost.get("bytes_accessed", 0.0) / HW.hbm_bw
    collective_s = coll.get("total_bytes", 0.0) / HW.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_global = cost.get("flops", 0.0) * chips
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flop_ratio": (mf / hlo_flops_global
                              if hlo_flops_global else None),
        "suggestion": _SUGGESTIONS[bottleneck],
    }
