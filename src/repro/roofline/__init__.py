from repro.roofline.analysis import (HW, collective_bytes_from_hlo,
                                     extract_cost, roofline_report)

__all__ = ["HW", "collective_bytes_from_hlo", "extract_cost",
           "roofline_report"]
