"""Analytic roofline calculator — executed FLOPs / HBM bytes / collective
bytes per (arch × shape × mesh), component by component.

Why analytic: XLA's ``cost_analysis()`` counts ``lax.scan``/while bodies
ONCE, not × trip-count (verified on this container; see EXPERIMENTS.md
§Roofline/methodology), so a scanned-60-layer model under-reports by ~2
orders of magnitude.  The calculator models the *executed* implementation
(including the 2× masked-full-rectangle waste of the jnp blocked-attention
path, MoE capacity padding, and remat recompute) so that perf iterations
show up in the numbers.  HLO-derived values are recorded alongside as a
cross-check on unrolled probes.

All byte/FLOP counts are GLOBAL per step; ``roofline_terms`` divides by
chip count / per-chip bandwidths at the end.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.roofline.analysis import HW


@dataclasses.dataclass
class MeshShape:
    dp: int          # data-parallel ways (pod*data)
    tp: int          # model/tensor ways

    @property
    def chips(self) -> int:
        return self.dp * self.tp


def mesh_shape_of(mesh) -> MeshShape:
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    return MeshShape(dp=dp, tp=mesh.shape.get("model", 1))


# ---------------------------------------------------------------------------
# Per-component FLOP model (executed, forward pass, global)
# ---------------------------------------------------------------------------


def _attn_flops(cfg, tokens, ctx, *, executed_ctx=None):
    """GQA attention: projections + scores/AV over context ``ctx``.
    ``executed_ctx`` = keys actually computed against (masked-full blocks)."""
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ec = executed_ctx if executed_ctx is not None else ctx
    proj = 2 * tokens * d * (H * hd + 2 * K * hd + H * hd)
    scores = 2 * tokens * ec * H * hd * 2          # QK^T + PV
    return proj + scores


def _mla_flops(cfg, tokens, ctx, *, decode=False, executed_ctx=None):
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ec = executed_ctx if executed_ctx is not None else ctx
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = 2 * tokens * (d * m.q_lora_rank + m.q_lora_rank * H * qk_head)
    kv_a = 2 * tokens * d * (m.kv_lora_rank + m.qk_rope_head_dim)
    o = 2 * tokens * H * m.v_head_dim * d
    if decode:
        # absorbed path: q_abs + latent scores + latent AV + uv expand
        absorb = 2 * tokens * H * m.qk_nope_head_dim * m.kv_lora_rank \
            + 2 * tokens * ec * H * (m.kv_lora_rank + m.qk_rope_head_dim) \
            + 2 * tokens * ec * H * m.kv_lora_rank \
            + 2 * tokens * H * m.kv_lora_rank * m.v_head_dim
        return q + kv_a + o + absorb
    kv_b = 2 * ctx * m.kv_lora_rank * H * (m.qk_nope_head_dim
                                           + m.v_head_dim)
    scores = 2 * tokens * ec * H * (qk_head + m.v_head_dim)
    return q + kv_a + kv_b + o + scores


def _ffn_flops(cfg, tokens, ff):
    nmat = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
    return 2 * nmat * tokens * cfg.d_model * ff


def _moe_flops(cfg, tokens):
    """Executed: capacity-padded expert GEMMs + router + shared expert."""
    expanded = tokens * cfg.experts_per_token
    if expanded > 4096:                      # matches moe._capacity
        expanded *= cfg.capacity_factor
    router = 2 * tokens * cfg.d_model * cfg.num_experts
    nmat = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
    experts = 2 * nmat * expanded * cfg.d_model * cfg.moe_d_ff
    shared = (_ffn_flops(cfg, tokens, cfg.moe_d_ff * cfg.num_shared_experts)
              if cfg.num_shared_experts else 0)
    return router + experts + shared


def _ssd_flops(cfg, tokens, *, decode=False):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.num_heads(d)
    P, G, N = s.head_dim, s.num_groups, s.d_state
    gn = G * N
    proj = 2 * tokens * d * (2 * di + 2 * gn + H) + 2 * tokens * di * d
    conv = 2 * tokens * (di + 2 * gn) * s.conv_width
    if decode:
        state = 2 * tokens * H * P * N * 2           # update + output
        return proj + conv + state
    Q = s.chunk_size
    intra = 2 * tokens * Q * G * N + 2 * tokens * Q * H * P * 2
    inter = 2 * tokens * H * P * N * 2               # states + y_off
    return proj + conv + intra + inter


def _layer_forward_flops(cfg, i, tokens, ctx, *, decode=False,
                         executed_ctx=None):
    if cfg.is_attn_layer(i):
        if cfg.use_mla:
            f = _mla_flops(cfg, tokens, ctx, decode=decode,
                           executed_ctx=executed_ctx)
        else:
            f = _attn_flops(cfg, tokens, ctx, executed_ctx=executed_ctx)
    else:
        f = _ssd_flops(cfg, tokens, decode=decode)
    if cfg.is_moe_layer(i):
        f += _moe_flops(cfg, tokens)
    elif cfg.d_ff:
        f += _ffn_flops(cfg, tokens, cfg.d_ff)
    return f


def forward_flops(cfg: ModelConfig, shape: ShapeConfig, *,
                  executed_attention: str = "full") -> Dict[str, float]:
    """Global forward FLOPs by component.

    executed_attention: 'full' = masked full rectangle (jnp blocked path),
    'causal' = triangular (Pallas block-skip), relevant to train/prefill.
    """
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    tokens = B * (1 if decode else S)
    ctx = S
    if decode:
        from repro.launch.steps import decode_window
        w = decode_window(cfg, shape)
        # H3: windowed decode slices the live cache window instead of
        # masking the full cache (before-state: executed_ctx = ctx).
        executed_ctx = min(w, ctx) if (w and ctx > 2 * w) else ctx
        useful_ctx = min(w, ctx) if w else ctx
    else:
        executed_ctx = ctx if executed_attention == "full" else (ctx + 1) / 2
        useful_ctx = (ctx + 1) / 2

    layers = 0.0
    for i in range(cfg.num_layers):
        layers += _layer_forward_flops(cfg, i, tokens, ctx, decode=decode,
                                       executed_ctx=executed_ctx)
    enc = 0.0
    if cfg.is_encoder_decoder:
        enc_tokens = 0 if decode else tokens
        for _ in range(cfg.num_encoder_layers):
            if enc_tokens:
                enc += _attn_flops(cfg, enc_tokens, S, executed_ctx=S)
                enc += _ffn_flops(cfg, enc_tokens, cfg.d_ff)
        # cross-attention inside decoder layers
        mem = cfg.encoder_seq_len if decode else S
        enc += cfg.num_layers * _attn_flops(cfg, tokens, mem,
                                            executed_ctx=mem)
    loss_tokens = tokens if shape.kind == "train" else B
    head = 2 * loss_tokens * cfg.d_model * cfg.vocab_size
    if cfg.mtp_depth and shape.kind == "train":
        head += 2 * tokens * cfg.d_model * cfg.vocab_size
        head += _layer_forward_flops(cfg, 0, tokens, ctx,
                                     executed_ctx=executed_ctx)
    return {"layers": layers, "encoder": enc, "head": head,
            "total": layers + enc + head}


def step_flops(cfg, shape, *, executed_attention="full") -> Dict[str, float]:
    """Executed FLOPs for the whole step (train = fwd+bwd+remat)."""
    fwd = forward_flops(cfg, shape, executed_attention=executed_attention)
    if shape.kind != "train":
        return dict(fwd, multiplier=1.0)
    # bwd = 2x fwd; full remat recomputes fwd once more
    mult = 4.0
    n_params = cfg.param_count()
    opt = 12.0 * n_params                 # adam elementwise update
    total = fwd["total"] * mult + opt
    return {"layers": fwd["layers"] * mult, "encoder": fwd["encoder"] * mult,
            "head": fwd["head"] * 3.0, "optimizer": opt,
            "multiplier": mult, "total": total}


# ---------------------------------------------------------------------------
# HBM byte model (global per step)
# ---------------------------------------------------------------------------


def _bytes_of(cfg):
    return 2 if cfg.param_dtype == "bfloat16" else 4


def cache_bytes(cfg, shape) -> float:
    B, S = shape.global_batch, shape.seq_len
    bts = 2 if cfg.compute_dtype == "bfloat16" else 4
    total = 0.0
    for i in range(cfg.num_layers):
        if cfg.is_attn_layer(i):
            if cfg.use_mla:
                m = cfg.mla
                total += B * S * (m.kv_lora_rank + m.qk_rope_head_dim) * bts
            else:
                total += 2 * B * S * cfg.num_kv_heads * cfg.head_dim * bts
        elif cfg.ssm is not None:
            s = cfg.ssm
            d = cfg.d_model
            total += B * s.num_heads(d) * s.head_dim * s.d_state * 4
            total += B * (s.conv_width - 1) * (s.d_inner(d)
                                               + 2 * s.num_groups * s.d_state) * bts
    if cfg.is_encoder_decoder:
        total += 2 * B * cfg.encoder_seq_len * cfg.kv_dim * 2 * bts
    return total


def step_bytes(cfg, shape, mesh: MeshShape, num_microbatches: int = 1
               ) -> Dict[str, float]:
    """Global HBM traffic model.  Terms documented inline."""
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    tokens = B * (1 if decode else S)
    pbytes = cfg.param_count() * _bytes_of(cfg)
    abytes = 2 if cfg.compute_dtype == "bfloat16" else 4
    G = num_microbatches

    terms: Dict[str, float] = {}
    if shape.kind == "train":
        # weights: read fwd + remat + bwd per microbatch (FSDP regathers)
        terms["weights"] = 3.0 * G * pbytes
        # optimizer: read m,v + write m,v,p + grads read/write (f32)
        terms["optimizer"] = 9.0 * cfg.param_count() * 4.0
        # activations: residual stream in/out per layer x 3 passes
        terms["activations"] = (cfg.num_layers
                                * 4.0 * tokens * cfg.d_model * abytes * 3.0)
    else:
        terms["weights"] = (cfg.active_param_count() if decode
                            else cfg.param_count()) * _bytes_of(cfg)
        terms["activations"] = (cfg.num_layers
                                * 4.0 * tokens * cfg.d_model * abytes)
    if shape.kind != "train":
        cb = cache_bytes(cfg, shape)
        if decode:
            # H3: windowed decode reads only the live window of the
            # attention caches (SSM caches are O(1) regardless).
            from repro.launch.steps import decode_window
            w = decode_window(cfg, shape)
            if w and S > 2 * w:
                cb = cb * (w / S)
        terms["kv_cache"] = cb
    # attention score traffic is kept on-chip by the blocked path (VMEM) —
    # only block-boundary spills modelled via activations term.
    terms["total"] = sum(v for k, v in terms.items() if k != "total")
    return terms


# ---------------------------------------------------------------------------
# Collective byte model (global per step)
# ---------------------------------------------------------------------------


def step_collective_bytes(cfg, shape, mesh: MeshShape,
                          num_microbatches: int = 1) -> Dict[str, float]:
    B, S = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    tokens = B * (1 if decode else S)
    abytes = 2 if cfg.compute_dtype == "bfloat16" else 4
    pbytes = cfg.param_count() * _bytes_of(cfg)
    G = num_microbatches
    dp, tp = mesh.dp, mesh.tp
    terms: Dict[str, float] = {}

    # H2: routed-expert params are EP-sharded over 'data' — they never
    # FSDP-gather or grad-reduce over that axis (tokens move instead).
    n_fsdp_params = cfg.param_count() - cfg.routed_expert_param_count()
    fsdp_bytes = n_fsdp_params * _bytes_of(cfg)
    if shape.kind == "train":
        # FSDP param all-gather: fwd + remat + bwd, per microbatch.
        terms["fsdp_allgather"] = 3.0 * G * fsdp_bytes * (dp - 1) / dp
        # gradient reduction over data axis (f32)
        terms["grad_reduce"] = 2.0 * n_fsdp_params * 4.0 * (dp - 1) / dp
    else:
        terms["weight_allgather"] = fsdp_bytes * (dp - 1) / dp  # serve read

    # tensor-parallel activation reductions: ~2 per layer per pass.
    # NOTE: each token makes 3 passes (fwd/remat/bwd) regardless of G —
    # microbatching moves tokens between passes, it doesn't add any.
    passes = 3.0 if shape.kind == "train" else 1.0
    n_tp_layers = cfg.num_layers
    terms["tp_allreduce"] = (2.0 * n_tp_layers * passes * tokens
                             * cfg.d_model * abytes * (tp - 1) / tp)

    # MoE all-to-all: expanded tokens out + back, per pass, over the
    # expert-parallel (data) axis per H2.
    n_moe = sum(cfg.is_moe_layer(i) for i in range(cfg.num_layers))
    if n_moe:
        expanded = tokens * cfg.experts_per_token
        terms["moe_all_to_all"] = (2.0 * n_moe * passes * expanded
                                   * cfg.d_model * abytes * (dp - 1) / dp)

    # loss/logit reductions (vocab sharded over tp)
    loss_tokens = tokens if shape.kind == "train" else B
    terms["logit_reduce"] = 3.0 * loss_tokens * 4.0 * (tp - 1) / tp * (
        2.0 if shape.kind == "train" else 1.0)
    if decode:
        # flash-decode partial-softmax combine per attention layer
        n_attn = sum(cfg.is_attn_layer(i) for i in range(cfg.num_layers))
        heads = cfg.num_heads
        dv = (cfg.mla.v_head_dim if cfg.use_mla else cfg.head_dim)
        terms["decode_softmax_combine"] = (n_attn * B * heads
                                           * (dv + 2) * 4.0 * (tp - 1) / tp)
        # token logits all-gather to host
        terms["logit_gather"] = B * cfg.vocab_size * 4.0 * (tp - 1) / tp

    terms["total"] = sum(v for k, v in terms.items() if k != "total")
    return terms


# ---------------------------------------------------------------------------
# Assembled roofline
# ---------------------------------------------------------------------------


def roofline_terms(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   num_microbatches: int = 1,
                   executed_attention: str = "full") -> Dict:
    ms = mesh_shape_of(mesh) if not isinstance(mesh, MeshShape) else mesh
    fl = step_flops(cfg, shape, executed_attention=executed_attention)
    by = step_bytes(cfg, shape, ms, num_microbatches)
    co = step_collective_bytes(cfg, shape, ms, num_microbatches)
    chips = ms.chips
    compute_s = fl["total"] / (chips * HW.peak_flops)
    memory_s = by["total"] / (chips * HW.hbm_bw)
    collective_s = co["total"] / (chips * HW.ici_bw)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    model_fl = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "bottleneck": bottleneck,
        "step_s_bound": max(terms.values()),
        "model_flops": model_fl,
        "executed_flops": fl["total"],
        "useful_flop_ratio": model_fl / fl["total"] if fl["total"] else None,
        "flops_breakdown": fl, "bytes_breakdown": by,
        "collective_breakdown": co, "chips": chips,
    }
