"""Gemma 2B — dense LM with MQA (kv=1), GeGLU, head_dim 256.

[arXiv:2403.08295]  18 layers, d_model 2048, 8 heads with a single shared
KV head (MQA), head_dim 256, d_ff 16384, GeGLU activation, vocab 256000,
tied embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    arch_type="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_act="geglu",
    tie_embeddings=True,
    source="arXiv:2403.08295 (Gemma)",
)
