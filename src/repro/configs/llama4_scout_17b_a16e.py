"""Llama-4 Scout 17B-A16E — MoE with top-1 routing + shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E]  48 layers, d_model 5120, 40 heads
(GQA kv=8), expert d_ff 8192, vocab 202048, 16 routed experts top-1 plus
one always-on shared expert on every layer (interleave step 1).  The
vision early-fusion frontend is a stub by assignment.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    experts_per_token=1,
    num_shared_experts=1,
    moe_d_ff=8192,
    mlp_act="swiglu",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
