"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887]  32 layers, d_model 4096, 32 heads (GQA kv=8),
d_ff 14336, vocab 65536, MoE 16 experts top-2 on every other layer,
attention on 1 of every 8 layers (offset 4).
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    attn_period=8,
    attn_offset=4,
    num_experts=16,
    experts_per_token=2,
    moe_d_ff=14336,
    moe_period=2,
    moe_offset=1,
    ssm=SSMConfig(d_state=16, head_dim=64, num_groups=1, conv_width=4,
                  chunk_size=256, expand=2),
    mlp_act="swiglu",
    source="arXiv:2403.19887 (Jamba: A Hybrid Transformer-Mamba Language Model)",
)
