"""Configuration dataclasses for the repro framework.

``ModelConfig`` is the single source of truth for a model architecture.  It
covers every architecture family assigned to this paper (dense / MoE / SSM /
hybrid / encoder-decoder audio / VLM) through optional fields; the per-arch
modules consume only the fields relevant to them.

``ShapeConfig`` describes an input workload (the four assigned shapes).

Both are frozen dataclasses so they can be closed over by jitted functions
and used as static arguments.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention hyper-parameters."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) hyper-parameters."""

    d_state: int = 128           # N
    head_dim: int = 64           # P
    num_groups: int = 1          # G (B/C groups)
    conv_width: int = 4
    chunk_size: int = 256        # Q for the chunked SSD algorithm
    expand: int = 2              # d_inner = expand * d_model

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    Layer-type pattern
    ------------------
    ``attn_period``/``attn_offset`` define which layers are attention in a
    hybrid model: layer ``i`` is attention iff ``i % attn_period ==
    attn_offset``.  A pure-attention model uses ``attn_period=1,
    attn_offset=0``; a pure-SSM model uses ``attn_period=0``.

    ``moe_period``/``moe_offset`` likewise select MoE FFN layers, with the
    first ``first_dense_layers`` layers forced dense (DeepSeek-V3 style).
    """

    name: str
    arch_type: str               # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- attention options ----------------------------------------------
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_window: Optional[int] = None      # sliding-window size, None = full
    long_context_window: int = 8192        # window used for long_500k decode
    rope_theta: float = 10_000.0
    use_mla: bool = False
    mla: MLAConfig = field(default_factory=MLAConfig)

    # --- layer pattern -----------------------------------------------------
    attn_period: int = 1
    attn_offset: int = 0

    # --- norms / MLP -------------------------------------------------------
    norm_eps: float = 1e-6
    mlp_act: str = "swiglu"                # swiglu | geglu | gelu | relu
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0                   # 0 => dense FFN everywhere
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                      # per-expert hidden dim
    first_dense_layers: int = 0
    moe_period: int = 1
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 0.0

    # --- SSM (mamba2 / hybrid) ----------------------------------------------
    ssm: Optional[SSMConfig] = None

    # --- encoder-decoder -----------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1024            # stub frontend memory length

    # --- modality frontend (stub by assignment) ------------------------------
    modality: str = "text"                 # text | audio | vision
    num_prefix_embeds: int = 0             # vision patches prepended to text

    # --- multi-token prediction (DeepSeek-V3) --------------------------------
    mtp_depth: int = 0

    # --- numerics ------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # --- citation -------------------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------
    # Derived helpers
    # ------------------------------------------------------------------

    def is_attn_layer(self, i: int) -> bool:
        if self.attn_period == 0:
            return False
        return i % self.attn_period == self.attn_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0 or i < self.first_dense_layers:
            return False
        return i % self.moe_period == self.moe_offset

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Approximate total parameter count (embedding + blocks + head)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # lm head
        layers = range(self.num_layers)
        for i in layers:
            if self.is_attn_layer(i):
                if self.use_mla:
                    m = self.mla
                    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                    n += d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_head
                    n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    n += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    n += self.num_heads * m.v_head_dim * d
                else:
                    n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            elif self.ssm is not None:
                di = self.ssm.d_inner(d)
                gn = self.ssm.num_groups * self.ssm.d_state
                h = self.ssm.num_heads(d)
                n += d * (2 * di + 2 * gn + h)        # in_proj
                n += di * d                           # out_proj
                n += (di + 2 * gn) * self.ssm.conv_width
            # FFN
            mult = 2 if self.mlp_act in ("swiglu", "geglu") else 1
            if self.is_moe_layer(i):
                n += d * self.num_experts             # router
                n += self.num_experts * (mult + 1) * d * self.moe_d_ff
                n += self.num_shared_experts * (mult + 1) * d * self.moe_d_ff
            else:
                if self.d_ff > 0:
                    n += (mult + 1) * d * self.d_ff
        if self.is_encoder_decoder:
            # encoder layers: self-attn + ffn; decoder already counted above.
            for _ in range(self.num_encoder_layers):
                n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                mult = 2 if self.mlp_act in ("swiglu", "geglu") else 1
                n += (mult + 1) * d * self.d_ff
            # cross-attention in every decoder layer
            n += self.num_layers * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d)
        return n

    def routed_expert_param_count(self) -> int:
        """Parameters living in the routed-expert tensors (EP-sharded over
        the data axis per §Perf H2 — excluded from FSDP gather/reduce)."""
        if self.num_experts == 0:
            return 0
        mult = 2 if self.mlp_act in ("swiglu", "geglu") else 1
        per_expert = (mult + 1) * self.d_model * self.moe_d_ff
        n_moe = sum(self.is_moe_layer(i) for i in range(self.num_layers))
        return n_moe * self.num_experts * per_expert

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed-to experts)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        mult = 2 if self.mlp_act in ("swiglu", "geglu") else 1
        per_expert = (mult + 1) * self.d_model * self.moe_d_ff
        num_moe_layers = sum(self.is_moe_layer(i) for i in range(self.num_layers))
        inactive = num_moe_layers * (self.num_experts - self.experts_per_token) * per_expert
        return full - inactive

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=256, <=4 experts.

        Keeps every structural feature (layer pattern, MoE, MLA, SSM,
        enc-dec) so smoke tests exercise the same code paths as the full
        config.
        """
        d = min(self.d_model, 256)
        heads = max(2, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        head_dim = max(16, min(self.head_dim, 32))
        nl = min(self.num_layers, 2)
        attn_period, attn_offset = self.attn_period, self.attn_offset
        if self.arch_type == "hybrid":
            # keep one mamba + one attn layer
            nl, attn_period, attn_offset = 2, 2, 1
        kw = dict(
            num_layers=nl,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 4 * d) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            attn_period=attn_period,
            attn_offset=attn_offset,
            first_dense_layers=min(self.first_dense_layers, 1),
            num_encoder_layers=min(self.num_encoder_layers, 2),
            encoder_seq_len=min(self.encoder_seq_len, 16),
            num_prefix_embeds=min(self.num_prefix_embeds, 4),
            mtp_depth=min(self.mtp_depth, 1),
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.num_experts:
            kw.update(
                num_experts=min(self.num_experts, 4),
                experts_per_token=min(self.experts_per_token, 2),
                moe_d_ff=min(self.moe_d_ff, 2 * d),
                num_shared_experts=min(self.num_shared_experts, 1),
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk_size=8)
        if self.use_mla:
            kw["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32,
                qk_nope_head_dim=head_dim, qk_rope_head_dim=16,
                v_head_dim=head_dim)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Workload shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input workloads."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode
    num_microbatches: int = 1    # gradient-accumulation factor (train only)


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train", num_microbatches=1)
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
