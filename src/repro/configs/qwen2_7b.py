"""Qwen2 7B — dense GQA LM with QKV bias.

[arXiv:2407.10671]  28 layers, d_model 3584, 28 heads (GQA kv=4,
head_dim 128), d_ff 18944, vocab 152064, bias on the QKV projections
(the Qwen2 signature).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
    source="arXiv:2407.10671 (Qwen2 Technical Report)",
)
