"""InternVL2-26B — VLM; InternLM2-20B language backbone + ViT stub.

[arXiv:2404.16821]  Language model: 48 layers, d_model 6144, 48 heads
(GQA kv=8), d_ff 16384, vocab 92553.  The InternViT-6B vision encoder +
MLP projector is a stub by assignment: ``input_specs`` supplies 256
projected patch embeddings (B, 256, d_model) prepended to the text
sequence; no LM loss on patch positions.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    arch_type="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    modality="vision",
    num_prefix_embeds=256,
    mlp_act="swiglu",
    source="arXiv:2404.16821 (InternVL 1.5/2 family)",
)
