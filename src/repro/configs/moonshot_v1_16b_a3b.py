"""Moonlight (Kimi) 16B-A3B — MoE LM, 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B]  Assignment spec: 48 layers, d_model
2048, 16 heads (kv=16, i.e. MHA), expert d_ff 1408, vocab 163840, MoE 64
experts top-6.  Following the Moonlight card we add 2 shared experts and
keep the first layer dense (dense d_ff = 8x expert width = 11264).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    arch_type="dense",               # assignment bracket
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=11264,                      # first dense layer
    vocab_size=163840,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    mlp_act="swiglu",
    source="hf:moonshotai/Moonlight-16B-A3B",
)
