"""SeamlessM4T-medium — speech encoder-decoder transformer backbone.

[arXiv:2308.11596]  12 encoder + 12 decoder layers, d_model 1024, 16 heads
(kv=16, head_dim 64), d_ff 4096, vocab 256206.  The mel-spectrogram +
conv feature extractor frontend is a stub by assignment: ``input_specs``
supplies precomputed frame embeddings (B, T_src, d_model).  Norms are
RMSNorm (adaptation from the original LayerNorm; DESIGN.md §8).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    num_layers=12,                   # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    is_encoder_decoder=True,
    num_encoder_layers=12,
    encoder_seq_len=1024,
    modality="audio",
    mlp_act="gelu",
    source="arXiv:2308.11596 (SeamlessM4T)",
)
