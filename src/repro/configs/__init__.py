"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Every assigned architecture is a selectable config (``--arch <id>`` in the
launchers).  Each module cites its source paper / model card.
"""

from __future__ import annotations

import importlib

from repro.configs.base import (MLAConfig, ModelConfig, ShapeConfig,
                                SSMConfig, SHAPES, TRAIN_4K, PREFILL_32K,
                                DECODE_32K, LONG_500K)

_ARCH_MODULES = {
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "gemma-2b": "repro.configs.gemma_2b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "qwen2-7b": "repro.configs.qwen2_7b",
}


def list_archs():
    return sorted(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = ["ModelConfig", "ShapeConfig", "MLAConfig", "SSMConfig",
           "get_config", "get_shape", "list_archs", "SHAPES",
           "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K"]
