"""Qwen3 14B — dense GQA LM with qk-norm.

[hf:Qwen/Qwen3-8B family]  Assignment spec: 40 layers, d_model 5120,
40 heads (GQA kv=8, head_dim 128), d_ff 17408, vocab 151936, per-head
RMS qk-norm (the Qwen3 signature), no QKV bias.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B (Qwen3 family card)",
)
