"""Mamba2 2.7B — attention-free SSM via SSD (state-space duality).

[arXiv:2405.21060]  64 layers, d_model 2560 (d_inner 5120, 80 heads of
P=64), state N=128, no FFN (d_ff=0), vocab 50280, tied embeddings.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attn_period=0,                   # attention-free
    ssm=SSMConfig(d_state=128, head_dim=64, num_groups=1, conv_width=4,
                  chunk_size=256, expand=2),
    tie_embeddings=True,
    source="arXiv:2405.21060 (Transformers are SSMs / Mamba-2)",
)
