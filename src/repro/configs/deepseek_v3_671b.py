"""DeepSeek-V3 671B — MLA + fine-grained MoE (1 shared + 256 routed top-8).

[arXiv:2412.19437]  61 layers (first 3 dense, d_ff 18432), d_model 7168,
128 MLA heads (q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128),
256 routed experts top-8 with expert d_ff 2048 (= the assignment's
"d_ff=2048"), 1 shared expert, vocab 129280, depth-1 MTP head.
"""

from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,                      # dense layers (first 3)
    vocab_size=129280,
    use_mla=True,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    num_experts=256,
    experts_per_token=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_dense_layers=3,
    mtp_depth=1,
    mlp_act="swiglu",
    source="arXiv:2412.19437 (DeepSeek-V3 Technical Report)",
)
