"""Runtime lock-order watchdog: assert the static order at acquisition.

The static analyzer (:mod:`repro.analysis.locks`) proves the lock-
acquisition *graph* is cycle-free; this module enforces the same
discipline dynamically in debug builds and threaded tests, where the
static analysis can't see through callbacks (e.g. the frontend's
``sizes_fn`` seal closure acquiring the tenant lock inside the server's
select lock).

:class:`OrderedLock` wraps a real lock with a numeric **rank**; a thread
may only acquire a lock whose rank is strictly greater than every lock
it already holds.  A violation raises :class:`LockOrderError`
immediately — turning a once-in-a-blue-moon deadlock hang into a
deterministic test failure at the exact acquisition site.

The canonical ranks for the serving stack (ascending = outermost
first)::

    SERVING_LOCK_ORDER = {
        "_registry_lock": 5,    # CohortFrontend tenant registry
        "_sched_lock": 15,      # DecodeScheduler slot table + queue
        "_select_lock": 20,     # CohortServer single-writer select/draw
        "_solve_lock": 24,      # engine entry: inline + background solves
        "lock": 30,             # _Tenant batch bookkeeping (via seal)
        "_write_lock": 32,      # embedding base table + delta buffer
        "_queue_lock": 34,      # BackgroundSolver dirty-tenant queue
        "_dedupe_lock": 35,     # SolveDeduper fingerprint registry
        "_publish_lock": 36,    # warmed (version, table, result) mailbox
        "_admission_lock": 38,  # AdmissionController tokens / depth
        "_stats_lock": 40,      # CohortServer counters (innermost)
    }

``_sched_lock`` is the LM path's scheduler lock (slot table, request
queue, KV caches in ``launch.serve.DecodeScheduler``); it is disjoint
from the cohort locks and only ever nests the innermost
``_stats_lock`` for its dashboard counters.

``_write_lock`` ranks *after* the select/tenant locks because
``snapshot()`` now materializes the pending-delta buffer under it, and
the select path snapshots while holding ``_select_lock`` (and the seal
callback may have taken the tenant ``lock`` just before).  The
streaming locks slot between it and ``_stats_lock``: a background
solver worker takes ``_queue_lock`` alone, then ``_dedupe_lock`` alone,
then ``_solve_lock`` alone, then ``_publish_lock`` — never while
holding ``_select_lock`` — so the serving path can never deadlock
against a background publish.

``instrument(obj, ranks)`` swaps an object's lock attributes for
watchdogged wrappers in place — used by ``tests/test_frontend.py`` and
``tests/test_streaming.py`` to run the coalescing/streaming herds with
order assertions on.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional

#: canonical acquisition order of the cohort-serving stack; see
#: docs/ANALYSIS.md ("Lock discipline") for the derivation.
SERVING_LOCK_ORDER: Dict[str, int] = {
    "_registry_lock": 5,
    "_sched_lock": 15,
    "_select_lock": 20,
    "_solve_lock": 24,
    "lock": 30,
    "_write_lock": 32,
    "_queue_lock": 34,
    "_dedupe_lock": 35,
    "_publish_lock": 36,
    "_admission_lock": 38,
    "_stats_lock": 40,
}


class LockOrderError(RuntimeError):
    """A thread acquired locks against the declared rank order."""


class _Held(threading.local):
    def __init__(self):
        self.stack: List["OrderedLock"] = []


_held = _Held()


class OrderedLock:
    """A lock wrapper asserting rank order at every acquisition.

    Drop-in for the ``with``-statement and ``acquire``/``release``
    subset of the :class:`threading.Lock` interface the serving stack
    uses.  Re-acquiring an already-held rank is also rejected (the
    serving locks are non-reentrant).
    """

    def __init__(self, name: str, rank: int,
                 lock: Optional[threading.Lock] = None):
        self.name = name
        self.rank = rank
        self._lock = lock if lock is not None else threading.Lock()

    def _check(self) -> None:
        for held in _held.stack:
            if held.rank >= self.rank:
                raise LockOrderError(
                    f"lock-order violation: acquiring {self.name!r} "
                    f"(rank {self.rank}) while holding {held.name!r} "
                    f"(rank {held.rank}); declared order requires "
                    f"strictly increasing ranks")

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._check()
        got = (self._lock.acquire(blocking, timeout) if timeout != -1
               else self._lock.acquire(blocking))
        if got:
            _held.stack.append(self)
        return got

    def release(self) -> None:
        if _held.stack and _held.stack[-1] is self:
            _held.stack.pop()
        else:  # out-of-LIFO release: still drop our entry if present
            for i in range(len(_held.stack) - 1, -1, -1):
                if _held.stack[i] is self:
                    del _held.stack[i]
                    break
        self._lock.release()

    def __enter__(self) -> "OrderedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._lock.locked()


def held_names() -> List[str]:
    """Names of the locks the calling thread currently holds."""
    return [lk.name for lk in _held.stack]


def instrument(obj, ranks: Optional[Dict[str, int]] = None,
               prefix: str = "") -> List[str]:
    """Replace ``obj``'s lock attributes with :class:`OrderedLock`.

    Every attribute of ``obj`` named in ``ranks`` (default
    :data:`SERVING_LOCK_ORDER`) that currently holds a lock-like object
    is swapped for an ``OrderedLock`` of that rank.  Returns the names
    instrumented.  ``prefix`` disambiguates instances in error messages
    (e.g. the tenant name).
    """
    ranks = ranks if ranks is not None else SERVING_LOCK_ORDER
    done = []
    for attr, rank in ranks.items():
        cur = getattr(obj, attr, None)
        if cur is None or isinstance(cur, OrderedLock):
            continue
        if not (hasattr(cur, "acquire") and hasattr(cur, "release")):
            continue
        name = f"{prefix}{type(obj).__name__}.{attr}"
        setattr(obj, attr, OrderedLock(name, rank))
        done.append(attr)
    return done
