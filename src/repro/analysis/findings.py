"""Finding model, suppression comments, and the committed baseline.

A :class:`Finding` is one rule violation at one source location.  Two
escape hatches keep the analyzer deployable on a living tree:

* **Inline suppressions** — a ``# repro-lint: ignore[rule-id]`` comment
  on the flagged line (or alone on the line directly above it) silences
  that rule there; ``# repro-lint: ignore`` with no bracket silences
  every rule on the line.  Suppressions are for *intentional* deviations
  (e.g. a deliberately fixed PRNG seed) and should carry a rationale in
  the same comment.

* **The baseline** — ``.repro-lint-baseline.json`` grandfathers findings
  that predate the analyzer.  ``--check`` fails only on findings NOT in
  the baseline; ``--update-baseline`` rewrites it from the current tree.
  Entries are fingerprinted on (rule, path, symbol, stripped source
  line) rather than line numbers, so unrelated edits don't churn it.
  Baseline entries whose finding has disappeared are *stale* and
  reported so they can be expired with ``--update-baseline``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Tuple

#: rule-id -> one-line description; the single registry every rule
#: family registers into (see docs/ANALYSIS.md for the full catalog).
RULES: Dict[str, str] = {
    "jax-host-time": (
        "wall-clock call (time.time/perf_counter/...) inside code traced "
        "by jax.jit/shard_map/pallas_call — the value freezes at trace "
        "time"),
    "jax-host-random": (
        "np.random / stdlib random inside traced code — untracked "
        "host-side entropy breaks reproducibility and freezes at trace "
        "time; use jax.random with an explicit key"),
    "jax-host-sync": (
        ".item() / float() / np.asarray() on a traced value — aborts "
        "tracing or forces a device sync inside the traced region"),
    "jax-blocking-sync": (
        "float()/.item() on the result of a jitted call — blocks the "
        "host on device compute in a hot path; defer materialization"),
    "prng-constant-key": (
        "jax.random.PRNGKey(<literal>) inside traced code — keys must "
        "enter as parameters or derive via split/fold_in"),
    "prng-key-reuse": (
        "the same PRNG key variable fed to two sampling calls — "
        "identical streams; split or fold_in between uses"),
    "pallas-interpret": (
        "pl.pallas_call wrapper does not plumb an interpret= kwarg — "
        "kernels must stay runnable off-TPU for the ref-oracle tests"),
    "pallas-static-args": (
        "block-size parameters of a pallas_call wrapper not declared in "
        "jax.jit static_argnames — every distinct size retraces or "
        "fails under tracing"),
    "pallas-ref-oracle": (
        "<name>_pallas wrapper has no same-named <name>_ref oracle in "
        "the package's ref.py — the kernel is untestable against "
        "ground truth"),
    "lock-guarded-by": (
        "attribute annotated '# guarded-by: <lock>' mutated outside a "
        "'with self.<lock>:' block"),
    "lock-order-cycle": (
        "cycle in the static lock-acquisition graph — a potential "
        "deadlock under concurrent callers"),
}

_SUPPRESS = re.compile(r"#\s*repro-lint:\s*ignore(?:\[([a-z0-9_,\- ]+)\])?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str                  # repo-relative, forward slashes
    line: int                  # 1-indexed
    message: str
    symbol: str = ""           # enclosing function/class qualname
    source: str = ""           # stripped source line (baseline anchor)

    def fingerprint(self) -> str:
        basis = f"{self.rule}|{self.path}|{self.symbol}|{self.source}"
        return hashlib.sha1(basis.encode()).hexdigest()[:16]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint()
        return d

    def render(self) -> str:
        sym = f" ({self.symbol})" if self.symbol else ""
        return f"{self.path}:{self.line}: [{self.rule}]{sym} {self.message}"


class Suppressions:
    """Per-file ``# repro-lint: ignore[...]`` comment index."""

    def __init__(self, source: str):
        # line number (1-indexed) -> set of suppressed rule ids
        # (empty set == suppress everything on that line)
        self._by_line: Dict[int, Optional[set]] = {}
        for i, text in enumerate(source.splitlines(), start=1):
            m = _SUPPRESS.search(text)
            if not m:
                continue
            rules = (set(r.strip() for r in m.group(1).split(","))
                     if m.group(1) else None)      # None == all rules
            self._by_line[i] = rules
            # a comment alone on its line also covers the line below
            if text.split("#", 1)[0].strip() == "":
                self._by_line[i + 1] = rules

    def covers(self, line: int, rule: str) -> bool:
        if line not in self._by_line:
            return False
        rules = self._by_line[line]
        return rules is None or rule in rules


def filter_suppressed(findings: Iterable[Finding],
                      sources: Dict[str, str]) -> List[Finding]:
    """Drop findings silenced by an inline comment in their file."""
    cache: Dict[str, Suppressions] = {}
    kept = []
    for f in findings:
        if f.path not in cache:
            cache[f.path] = Suppressions(sources.get(f.path, ""))
        if not cache[f.path].covers(f.line, f.rule):
            kept.append(f)
    return kept


# -- baseline --------------------------------------------------------------

def load_baseline(path: pathlib.Path) -> List[dict]:
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return list(data.get("findings", []))


def save_baseline(path: pathlib.Path, findings: Iterable[Finding]) -> None:
    entries = sorted((f.to_dict() for f in findings),
                     key=lambda d: (d["path"], d["rule"], d["line"]))
    path.write_text(json.dumps(
        {"comment": "repro-lint grandfathered findings; regenerate with "
                    "scripts/lint.py --update-baseline",
         "findings": entries}, indent=2) + "\n")


def apply_baseline(findings: List[Finding], baseline: List[dict],
                   ) -> Tuple[List[Finding], List[dict]]:
    """Split into (new findings, stale baseline entries)."""
    current = {f.fingerprint() for f in findings}
    known = {e["fingerprint"] for e in baseline}
    new = [f for f in findings if f.fingerprint() not in known]
    stale = [e for e in baseline if e["fingerprint"] not in current]
    return new, stale
