"""Rule family 3: lock discipline for the threaded serving stack.

Two rules over any analyzed file that uses ``threading`` locks (in this
tree: ``launch/serve.py`` and ``launch/frontend.py``):

* ``lock-guarded-by`` — an attribute whose declaration carries a
  ``# guarded-by: <lock>`` comment may only be **mutated** inside a
  ``with <obj>.<lock>:`` block on the *same* object.  Mutation means
  attribute assignment, augmented assignment, subscript stores, or
  calls to known mutating container methods (``append``/``update``/
  ``pop``/...).  ``__init__`` is exempt (single-threaded
  construction); *reads* are deliberately out of scope — several fields
  here are read lock-free by design (immutable snapshot swaps).

* ``lock-order-cycle`` — a static lock-acquisition graph is built
  across methods: an edge A -> B is recorded when a ``with`` on B nests
  (lexically, or through a resolvable method call) inside a ``with`` on
  A.  A cycle means two threads can acquire the locks in opposite
  orders — a potential deadlock.  Lock identity is ``Class.attr``
  (locks are discovered from ``self.X = threading.Lock()``-shaped
  assignments).

The static order is the ground truth the runtime watchdog
(:mod:`repro.analysis.watchdog`) asserts in debug builds/threaded tests.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.callgraph import ModuleIndex, TreeIndex, dotted
from repro.analysis.findings import Finding

_GUARDED_BY = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")
_MUTATORS = {"append", "update", "pop", "clear", "extend", "add",
             "remove", "discard", "insert", "setdefault", "popitem",
             "appendleft", "popleft"}


def _src_line(mi: ModuleIndex, line: int) -> str:
    lines = mi.source.splitlines()
    return lines[line - 1].strip() if 0 < line <= len(lines) else ""


def _is_lock_ctor(value: ast.AST) -> bool:
    if not isinstance(value, ast.Call):
        return False
    head = dotted(value.func)
    return bool(head) and head.split(".")[-1] in (
        "Lock", "RLock", "OrderedLock")


@dataclasses.dataclass
class ClassLocks:
    """Lock attrs + guarded-by annotations declared by one class."""
    module: ModuleIndex
    cls: str
    locks: Set[str] = dataclasses.field(default_factory=set)
    guarded: Dict[str, str] = dataclasses.field(default_factory=dict)


def _scan_class(mi: ModuleIndex, cls: ast.ClassDef) -> ClassLocks:
    info = ClassLocks(mi, cls.name)
    lines = mi.source.splitlines()
    for node in ast.walk(cls):
        targets: List[ast.AST] = []
        value: Optional[ast.AST] = None
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.target is not None:
            targets, value = [node.target], node.value
        for tgt in targets:
            if not (isinstance(tgt, ast.Attribute)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == "self"):
                continue
            if value is not None and _is_lock_ctor(value):
                info.locks.add(tgt.attr)
            text = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
            m = _GUARDED_BY.search(text)
            if m:
                info.guarded[tgt.attr] = m.group(1)
    return info


@dataclasses.dataclass
class _MethodSummary:
    """Per-method lock behavior, for the cross-method graph."""
    qualname: str                                # "module.rel:Cls.m"
    acquires: Set[str] = dataclasses.field(default_factory=set)
    # (held locks at call site, callee method name, line)
    calls: List[Tuple[Tuple[str, ...], str, int]] = \
        dataclasses.field(default_factory=list)


class _LockVisitor(ast.NodeVisitor):
    """Walks one method tracking the stack of held ``with`` locks."""

    def __init__(self, checker: "LockChecker", mi: ModuleIndex,
                 cls: str, method: str):
        self.checker = checker
        self.mi = mi
        self.cls = cls
        self.method = method
        self.summary = _MethodSummary(f"{mi.rel}:{cls}.{method}")
        # parallel stacks: lock node ids / raw "base.attr" strings
        self.held_ids: List[str] = []
        self.held_raw: List[str] = []

    # -- with blocks ------------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        entered = 0
        for item in node.items:
            expr = item.context_expr
            if isinstance(expr, ast.Attribute) \
                    and isinstance(expr.value, (ast.Name, ast.Attribute)):
                lock_id = self.checker.lock_node_id(
                    self.mi, self.cls, expr)
                if lock_id is not None:
                    raw = ast.unparse(expr)
                    for held in self.held_ids:
                        self.checker.add_edge(held, lock_id,
                                              self.mi.rel, expr.lineno)
                    self.summary.acquires.add(lock_id)
                    self.held_ids.append(lock_id)
                    self.held_raw.append(raw)
                    entered += 1
        for stmt in node.body:
            self.visit(stmt)
        for _ in range(entered):
            self.held_ids.pop()
            self.held_raw.pop()

    # -- mutations --------------------------------------------------------
    def _check_mutation(self, target: ast.AST, line: int) -> None:
        attr_node: Optional[ast.Attribute] = None
        if isinstance(target, ast.Attribute):
            attr_node = target
        elif isinstance(target, ast.Subscript) \
                and isinstance(target.value, ast.Attribute):
            attr_node = target.value
        if attr_node is None or not isinstance(
                attr_node.value, (ast.Name, ast.Attribute)):
            return
        lock = self.checker.guard_for(self.mi, self.cls, attr_node)
        if lock is None or self.method == "__init__":
            return
        base = ast.unparse(attr_node.value)
        want = f"{base}.{lock}"
        if want not in self.held_raw:
            self.checker.findings.append(Finding(
                rule="lock-guarded-by", path=self.mi.rel, line=line,
                symbol=f"{self.cls}.{self.method}",
                source=_src_line(self.mi, line),
                message=(f"'{base}.{attr_node.attr}' is annotated "
                         f"guarded-by: {lock} but is mutated outside "
                         f"'with {want}:'")))

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            # top-level target shapes only (incl. tuple unpacking); a
            # blind ast.walk would visit both a Subscript and its inner
            # Attribute and report the same mutation twice
            elts = (tgt.elts if isinstance(tgt, (ast.Tuple, ast.List))
                    else [tgt])
            for t in elts:
                self._check_mutation(t, node.lineno)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_mutation(node.target, node.lineno)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._check_mutation(node.target, node.lineno)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        # container mutators: self._counters.update(...), pools[c].pop()
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _MUTATORS \
                and isinstance(node.func.value, ast.Attribute):
            self._check_mutation(node.func.value, node.lineno)
        head = dotted(node.func)
        if head:
            self.summary.calls.append(
                (tuple(self.held_ids), head.split(".")[-1], node.lineno))
        self.generic_visit(node)

    # methods' nested defs run in the same thread context; keep walking
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        for stmt in node.body:
            self.visit(stmt)

    visit_AsyncFunctionDef = visit_FunctionDef


class LockChecker:
    def __init__(self, tree: TreeIndex):
        self.tree = tree
        self.findings: List[Finding] = []
        #: (module rel, class) -> ClassLocks
        self.class_locks: Dict[Tuple[str, str], ClassLocks] = {}
        #: lock attr name -> {class names defining it}
        self.lock_owners: Dict[str, Set[str]] = {}
        #: guarded attr name -> (lock, class) for cross-object checks
        self.guard_by_attr: Dict[str, Tuple[str, str]] = {}
        #: edges: (A, B) -> (path, line) first site
        self.edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
        self.summaries: Dict[str, List[_MethodSummary]] = {}

        for rel, mi in tree.modules.items():
            for cls in mi.classes.values():
                info = _scan_class(mi, cls)
                if info.locks or info.guarded:
                    self.class_locks[(rel, cls.name)] = info
                    for lock in info.locks:
                        self.lock_owners.setdefault(lock, set()).add(
                            cls.name)
                    for attr, lock in info.guarded.items():
                        self.guard_by_attr.setdefault(
                            attr, (lock, cls.name))

    # -- resolution helpers ----------------------------------------------
    def lock_node_id(self, mi: ModuleIndex, cls: str,
                     expr: ast.Attribute) -> Optional[str]:
        """'self._select_lock' / 't.lock' -> 'Class.lockattr' or None."""
        attr = expr.attr
        if attr not in self.lock_owners:
            return None
        if isinstance(expr.value, ast.Name) and expr.value.id == "self" \
                and cls in self.lock_owners[attr]:
            return f"{cls}.{attr}"
        owners = self.lock_owners[attr]
        if len(owners) == 1:
            return f"{next(iter(owners))}.{attr}"
        return None

    def guard_for(self, mi: ModuleIndex, cls: str,
                  attr_node: ast.Attribute) -> Optional[str]:
        attr = attr_node.attr
        is_self = (isinstance(attr_node.value, ast.Name)
                   and attr_node.value.id == "self")
        if is_self:
            info = self.class_locks.get((mi.rel, cls))
            return info.guarded.get(attr) if info else None
        got = self.guard_by_attr.get(attr)
        return got[0] if got else None

    def add_edge(self, a: str, b: str, path: str, line: int) -> None:
        if a != b:
            self.edges.setdefault((a, b), (path, line))

    # -- cross-method propagation -----------------------------------------
    def _transitive_acquires(self) -> Dict[str, Set[str]]:
        """Method name -> locks acquired directly or via known calls."""
        by_name: Dict[str, List[_MethodSummary]] = {}
        for summaries in self.summaries.values():
            for s in summaries:
                by_name.setdefault(s.qualname.split(".")[-1],
                                   []).append(s)
        acq = {name: set().union(*(s.acquires for s in ss))
               for name, ss in by_name.items()}
        changed = True
        while changed:
            changed = False
            for name, ss in by_name.items():
                for s in ss:
                    for _, callee, _ in s.calls:
                        extra = acq.get(callee, set()) - acq[name]
                        if extra:
                            acq[name] |= extra
                            changed = True
        return acq

    def propagate_call_edges(self) -> None:
        acq = self._transitive_acquires()
        for rel, summaries in self.summaries.items():
            for s in summaries:
                for held, callee, line in s.calls:
                    if not held or callee not in acq:
                        continue
                    for b in acq[callee]:
                        for a in held:
                            self.add_edge(a, b, rel, line)

    # -- cycle detection --------------------------------------------------
    def find_cycles(self) -> List[List[str]]:
        graph: Dict[str, Set[str]] = {}
        for (a, b) in self.edges:
            graph.setdefault(a, set()).add(b)
            graph.setdefault(b, set())
        cycles: List[List[str]] = []
        seen_cycles: Set[Tuple[str, ...]] = set()

        def dfs(node: str, path: List[str], on_path: Set[str],
                done: Set[str]) -> None:
            on_path.add(node)
            path.append(node)
            for nxt in graph.get(node, ()):
                if nxt in on_path:
                    cyc = path[path.index(nxt):] + [nxt]
                    key = tuple(sorted(set(cyc)))
                    if key not in seen_cycles:
                        seen_cycles.add(key)
                        cycles.append(cyc)
                elif nxt not in done:
                    dfs(nxt, path, on_path, done)
            on_path.discard(node)
            path.pop()
            done.add(node)

        done: Set[str] = set()
        for node in sorted(graph):
            if node not in done:
                dfs(node, [], set(), done)
        return cycles

    # -- entry point ------------------------------------------------------
    def run(self) -> List[Finding]:
        for rel, mi in sorted(self.tree.modules.items()):
            summaries: List[_MethodSummary] = []
            for qual, fi in sorted(mi.functions.items()):
                if not isinstance(fi.node, (ast.FunctionDef,
                                            ast.AsyncFunctionDef)):
                    continue
                cls = fi.cls or ""
                visitor = _LockVisitor(self, mi, cls,
                                       fi.node.name)
                for stmt in fi.node.body:
                    visitor.visit(stmt)
                summaries.append(visitor.summary)
            self.summaries[rel] = summaries
        self.propagate_call_edges()
        for cyc in self.find_cycles():
            first_edge = (cyc[0], cyc[1]) if len(cyc) > 1 else None
            path, line = self.edges.get(first_edge, ("", 1))
            self.findings.append(Finding(
                rule="lock-order-cycle", path=path or "<graph>",
                line=line, symbol="",
                source="",
                message=("lock-acquisition cycle "
                         + " -> ".join(cyc)
                         + " — threads taking these locks in opposite "
                           "orders can deadlock")))
        return self.findings


def check(tree: TreeIndex) -> List[Finding]:
    return LockChecker(tree).run()
