"""repro-lint driver: walk the tree, run every rule family, report.

Usage (all equivalent):

    PYTHONPATH=src python scripts/lint.py [paths...] [flags]
    PYTHONPATH=src python -m repro.analysis [paths...] [flags]
    repro-lint [paths...] [flags]              (installed entry point)

Flags:
    --check             exit 1 on findings not in the baseline (CI mode)
    --json              machine-readable output (findings + summary)
    --baseline FILE     baseline path (default .repro-lint-baseline.json)
    --update-baseline   rewrite the baseline from the current findings
    --no-baseline       ignore the baseline entirely
    --list-rules        print the rule catalog and exit

Default path is ``src`` — the analyzer runs on the shipped package, not
the tests (fixtures under tests/analysis_fixtures are deliberately
non-compliant and exercised by tests/test_analysis.py directly).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Iterable, List, Tuple

from repro.analysis import locks, pallas_rules, purity
from repro.analysis.callgraph import TreeIndex
from repro.analysis.findings import (Finding, RULES, apply_baseline,
                                     filter_suppressed, load_baseline,
                                     save_baseline)

DEFAULT_BASELINE = ".repro-lint-baseline.json"


def _collect_files(paths: Iterable[str],
                   root: pathlib.Path) -> List[Tuple[pathlib.Path, str]]:
    files: List[Tuple[pathlib.Path, str]] = []
    for raw in paths:
        p = pathlib.Path(raw)
        if not p.is_absolute():
            p = root / p
        if p.is_file() and p.suffix == ".py":
            candidates = [p]
        elif p.is_dir():
            candidates = sorted(p.rglob("*.py"))
        else:
            raise FileNotFoundError(f"no such path: {raw}")
        for f in candidates:
            try:
                rel = f.resolve().relative_to(root.resolve()).as_posix()
            except ValueError:
                rel = f.as_posix()
            files.append((f, rel))
    return files


def analyze_paths(paths: Iterable[str],
                  root: pathlib.Path | None = None) -> List[Finding]:
    """Run every rule family over ``paths``; suppressions applied,
    baseline NOT applied (that's the caller's policy decision)."""
    root = root or pathlib.Path.cwd()
    tree = TreeIndex(_collect_files(paths, root))
    findings: List[Finding] = []
    findings += purity.check(tree)
    findings += pallas_rules.check(tree)
    findings += locks.check(tree)
    findings = filter_suppressed(findings, tree.sources())
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="JAX-aware static analysis: purity/PRNG, Pallas "
                    "kernel discipline, lock discipline.")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to analyze (default: src)")
    ap.add_argument("--check", action="store_true",
                    help="CI mode: exit 1 on findings not in the baseline")
    ap.add_argument("--json", action="store_true", dest="as_json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update-baseline", action="store_true")
    ap.add_argument("--no-baseline", action="store_true")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(RULES):
            print(f"{rule:20s} {RULES[rule]}")
        return 0

    root = pathlib.Path.cwd()
    paths = args.paths or ["src"]
    try:
        findings = analyze_paths(paths, root)
    except FileNotFoundError as e:
        print(f"repro-lint: {e}", file=sys.stderr)
        return 2

    baseline_path = pathlib.Path(args.baseline)
    if not baseline_path.is_absolute():
        baseline_path = root / baseline_path

    if args.update_baseline:
        save_baseline(baseline_path, findings)
        print(f"baseline updated: {len(findings)} finding(s) -> "
              f"{baseline_path}")
        return 0

    baseline = [] if args.no_baseline else load_baseline(baseline_path)
    new, stale = apply_baseline(findings, baseline)

    if args.as_json:
        print(json.dumps({
            "findings": [f.to_dict() for f in new],
            "baselined": len(findings) - len(new),
            "stale_baseline_entries": stale,
            "total": len(findings),
        }, indent=2))
    else:
        for f in new:
            print(f.render())
        grandfathered = len(findings) - len(new)
        bits = [f"{len(new)} finding(s)"]
        if grandfathered:
            bits.append(f"{grandfathered} baselined")
        if stale:
            bits.append(f"{len(stale)} stale baseline entrie(s) — "
                        f"run --update-baseline to expire")
        print("repro-lint: " + ", ".join(bits))

    if args.check:
        return 1 if new else 0
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
