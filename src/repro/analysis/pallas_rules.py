"""Rule family 2: Pallas kernel-wrapper discipline.

The kernels package convention (``src/repro/kernels``): every kernel
lives in ``<name>_pallas.py`` as a public wrapper ``<name>_pallas(...)``
around ``pl.pallas_call``, with a pure-jnp oracle ``<name>_ref`` in the
sibling ``ref.py``.  Three machine-checked rules keep that convention
honest:

* ``pallas-interpret``   — the wrapper must take an ``interpret``
  parameter and pass ``interpret=`` through to ``pl.pallas_call``;
  otherwise the kernel cannot run on the CPU CI (or be cross-checked
  against its oracle) at all.
* ``pallas-static-args`` — block-size parameters (``block_*``) and
  ``interpret`` shape the grid/specs, so they must be declared static
  (``functools.partial(jax.jit, static_argnames=(...))``); a traced
  block size fails at trace time, an unjitted wrapper silently
  retraces downstream.
* ``pallas-ref-oracle``  — for every ``<name>_pallas`` wrapper a
  ``<name>_ref`` symbol must exist in the package's ``ref.py``
  (cross-checked by symbol table, aliases count).
"""

from __future__ import annotations

import ast
import pathlib
from typing import List, Optional, Set

from repro.analysis.callgraph import ModuleIndex, TreeIndex, dotted
from repro.analysis.findings import Finding


def _src_line(mi: ModuleIndex, line: int) -> str:
    lines = mi.source.splitlines()
    return lines[line - 1].strip() if 0 < line <= len(lines) else ""


def _pallas_calls(fn: ast.AST) -> List[ast.Call]:
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            head = dotted(node.func)
            if head and head.split(".")[-1] == "pallas_call":
                out.append(node)
    return out


def _static_argnames(fn: ast.FunctionDef) -> Optional[Set[str]]:
    """static_argnames of a partial(jax.jit, ...) decorator, if any."""
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        head = dotted(dec.func)
        if not head or head.split(".")[-1] not in ("partial", "jit"):
            continue
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                names: Set[str] = set()
                for sub in ast.walk(kw.value):
                    if isinstance(sub, ast.Constant) \
                            and isinstance(sub.value, str):
                        names.add(sub.value)
                return names
    return None


def _ref_symbols(tree: TreeIndex, mi: ModuleIndex) -> Optional[Set[str]]:
    """Top-level symbols of the sibling ref.py, if one is indexed."""
    ref_rel = str(pathlib.PurePosixPath(mi.rel).parent / "ref.py")
    ref = tree.modules.get(ref_rel)
    if ref is None:
        return None
    symbols = set(ref.functions)
    for node in ref.tree.body:                 # aliases: `x_ref = y_ref`
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    symbols.add(tgt.id)
    return symbols


def check(tree: TreeIndex) -> List[Finding]:
    findings: List[Finding] = []
    for rel, mi in sorted(tree.modules.items()):
        for qual, fi in sorted(mi.functions.items()):
            fn = fi.node
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            calls = _pallas_calls(fn)
            if not calls:
                continue
            params = fi.params

            # interpret= must be a wrapper parameter AND reach the call
            plumbed = any(kw.arg == "interpret"
                          for c in calls for kw in c.keywords)
            if "interpret" not in params or not plumbed:
                findings.append(Finding(
                    rule="pallas-interpret", path=rel, line=fn.lineno,
                    symbol=qual, source=_src_line(mi, fn.lineno),
                    message=(f"'{qual}' wraps pl.pallas_call but does not "
                             f"plumb an interpret= kwarg through — the "
                             f"kernel cannot run off-TPU for oracle "
                             f"cross-checks")))

            # static declaration of block sizes (+ interpret)
            need_static = {p for p in params if p.startswith("block")}
            if "interpret" in params:
                need_static.add("interpret")
            if need_static:
                declared = _static_argnames(fn)
                missing = (need_static if declared is None
                           else need_static - declared)
                if missing:
                    findings.append(Finding(
                        rule="pallas-static-args", path=rel,
                        line=fn.lineno, symbol=qual,
                        source=_src_line(mi, fn.lineno),
                        message=(f"'{qual}': parameters "
                                 f"{sorted(missing)} shape the grid/"
                                 f"specs but are not in jax.jit "
                                 f"static_argnames (declare via "
                                 f"functools.partial(jax.jit, "
                                 f"static_argnames=...))")))

            # same-named oracle in the package's ref.py
            if qual.endswith("_pallas"):
                symbols = _ref_symbols(tree, mi)
                want = qual[: -len("_pallas")] + "_ref"
                if symbols is not None and want not in symbols:
                    findings.append(Finding(
                        rule="pallas-ref-oracle", path=rel,
                        line=fn.lineno, symbol=qual,
                        source=_src_line(mi, fn.lineno),
                        message=(f"'{qual}' has no oracle '{want}' in "
                                 f"{pathlib.PurePosixPath(rel).parent}/"
                                 f"ref.py — every kernel needs a pure-"
                                 f"jnp ground truth")))
    return findings
