"""AST index of the tree: functions, imports, call edges, jit roots.

The purity rules (``repro.analysis.purity``) only apply to code that jax
actually traces, so the central question this module answers is *which
functions are reachable from a trace entry point*.  A function is a
**trace root** when it is

* decorated with ``jax.jit`` (bare or via ``functools.partial(jax.jit,
  static_argnames=...)``),
* referenced inside a ``jax.jit(...)`` / ``shard_map(...)`` /
  ``pl.pallas_call(...)`` call expression anywhere in the tree
  (covers ``_grad = jax.jit(jax.value_and_grad(f))`` and kernel bodies
  handed to ``pallas_call``), or
* a lambda passed directly to one of those (the lambda body gets its
  own synthetic :class:`FunctionInfo`).

Reachability then follows call edges, resolved best-effort: bare names
against the module's functions and ``from``-imports, ``alias.attr``
against module import aliases, and ``self.method`` / ``Class.method``
against the class table.  Unresolvable calls (``jnp.dot``, callbacks,
higher-order arguments) are skipped — the analysis is deliberately an
under-approximation that favors precision over recall; the fixture
corpus pins the shapes it must catch.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
from typing import Dict, Iterable, List, Optional, Set, Tuple

#: call-expression heads whose function-valued arguments become trace
#: roots.  Matched on the LAST attribute segment so aliasing
#: (``from jax import jit``, ``pl.pallas_call``) doesn't matter.
TRACE_ENTRY_HEADS = ("jit", "shard_map", "pallas_call")


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_trace_entry(callnode: ast.Call) -> bool:
    head = dotted(callnode.func)
    return head is not None and head.split(".")[-1] in TRACE_ENTRY_HEADS


@dataclasses.dataclass(eq=False)      # identity hash: usable in sets
class FunctionInfo:
    qualname: str                       # "fn", "Cls.fn", "Cls.fn.<lambda>"
    node: ast.AST                       # FunctionDef / Lambda
    module: "ModuleIndex"
    cls: Optional[str] = None           # enclosing class name
    is_root: bool = False
    calls: List[Tuple[str, int]] = dataclasses.field(default_factory=list)

    @property
    def static_argnames(self) -> Set[str]:
        """Names declared static in a jax.jit decorator, if any."""
        names: Set[str] = set()
        for dec in getattr(self.node, "decorator_list", []):
            if not isinstance(dec, ast.Call):
                continue
            head = dotted(dec.func)
            if not head or head.split(".")[-1] not in ("partial", "jit"):
                continue
            for kw in dec.keywords:
                if kw.arg == "static_argnames":
                    for sub in ast.walk(kw.value):
                        if isinstance(sub, ast.Constant) \
                                and isinstance(sub.value, str):
                            names.add(sub.value)
        return names

    @property
    def params(self) -> Set[str]:
        a = self.node.args
        names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return set(names)

    def collect_calls(self) -> None:
        self.calls = [(head, n.lineno)
                      for n in ast.walk(self.node)
                      if isinstance(n, ast.Call)
                      and (head := dotted(n.func)) is not None]


class ModuleIndex:
    """One parsed file: functions, classes, imports, jit-wrapped names."""

    def __init__(self, path: pathlib.Path, rel: str, source: str):
        self.path = path
        self.rel = rel                          # repo-relative, "/" seps
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        self.functions: Dict[str, FunctionInfo] = {}
        self.classes: Dict[str, ast.ClassDef] = {}
        #: local alias -> imported module dotted path ("T" -> "x.y.z")
        self.import_modules: Dict[str, str] = {}
        #: local name -> (module dotted path, original name)
        self.import_names: Dict[str, Tuple[str, str]] = {}
        #: module-level / class-attr names bound to jax.jit(...) results
        self.jit_wrapped_names: Set[str] = set()
        self._index()

    # -- construction -----------------------------------------------------
    def _index(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                self._index_import(node)
        for node in self.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_function(node, cls=None)
            elif isinstance(node, ast.ClassDef):
                self.classes[node.name] = node
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        self._add_function(item, cls=node.name)
        self._index_roots()

    def _index_import(self, node: ast.AST) -> None:
        if isinstance(node, ast.Import):
            for alias in node.names:
                self.import_modules[alias.asname
                                    or alias.name.split(".")[0]] = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                local = alias.asname or alias.name
                self.import_names[local] = (node.module, alias.name)

    def _add_function(self, node, cls: Optional[str]) -> None:
        qual = f"{cls}.{node.name}" if cls else node.name
        info = FunctionInfo(qual, node, self, cls=cls)
        info.collect_calls()
        if any(self._jit_decorator(d) for d in node.decorator_list):
            info.is_root = True
        self.functions[qual] = info

    @staticmethod
    def _jit_decorator(dec: ast.AST) -> bool:
        head = dotted(dec)
        if head and head.split(".")[-1] == "jit":
            return True
        if isinstance(dec, ast.Call):
            # functools.partial(jax.jit, ...) / partial(jit, ...)
            h = dotted(dec.func)
            if h and h.split(".")[-1] == "partial" and dec.args:
                inner = dotted(dec.args[0])
                return bool(inner) and inner.split(".")[-1] == "jit"
            # jax.jit(...) used directly as a decorator factory
            h = dotted(dec.func)
            return bool(h) and h.split(".")[-1] == "jit"
        return False

    def _index_roots(self) -> None:
        """Mark functions referenced inside jit/shard_map/pallas_call."""
        lam_count = 0
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call) and _is_trace_entry(node)):
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Lambda):
                        lam_count += 1
                        qual = f"<jit-lambda-{lam_count}>"
                        info = FunctionInfo(qual, sub, self, is_root=True)
                        info.collect_calls()
                        self.functions[qual] = info
                    else:
                        name = None
                        if isinstance(sub, ast.Name):
                            name = sub.id
                        elif isinstance(sub, ast.Attribute):
                            name = sub.attr
                        if name is None:
                            continue
                        for qual, fi in self.functions.items():
                            if qual == name or qual.endswith(f".{name}"):
                                fi.is_root = True
        # module-level `X = jax.jit(...)` / `self.X = jax.jit(...)`
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                if not _is_trace_entry(node.value):
                    continue
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self.jit_wrapped_names.add(tgt.id)
                    elif isinstance(tgt, ast.Attribute):
                        self.jit_wrapped_names.add(tgt.attr)


class TreeIndex:
    """All modules of one analysis run plus cross-module resolution."""

    def __init__(self, files: Iterable[Tuple[pathlib.Path, str]]):
        self.modules: Dict[str, ModuleIndex] = {}
        #: dotted module path guess -> ModuleIndex (for import resolution)
        self._by_dotted: Dict[str, ModuleIndex] = {}
        for path, rel in files:
            mi = ModuleIndex(path, rel, path.read_text())
            self.modules[rel] = mi
            self._by_dotted[self._dotted_of(rel)] = mi

    @staticmethod
    def _dotted_of(rel: str) -> str:
        parts = pathlib.PurePosixPath(rel).with_suffix("").parts
        # strip a leading src/ layout segment if present
        if parts and parts[0] == "src":
            parts = parts[1:]
        return ".".join(parts)

    def sources(self) -> Dict[str, str]:
        return {rel: mi.source for rel, mi in self.modules.items()}

    # -- resolution -------------------------------------------------------
    def resolve(self, mi: ModuleIndex, caller: FunctionInfo,
                head: str) -> Optional[FunctionInfo]:
        """Best-effort: call head string -> FunctionInfo in the tree."""
        parts = head.split(".")
        if len(parts) == 1:
            name = parts[0]
            if name in mi.functions:
                return mi.functions[name]
            if name in mi.import_names:
                modpath, orig = mi.import_names[name]
                target = self._module_for(modpath)
                if target and orig in target.functions:
                    return target.functions[orig]
            return None
        base, rest = parts[0], parts[1:]
        if base in ("self", "cls") and caller.cls and len(rest) == 1:
            return mi.functions.get(f"{caller.cls}.{rest[0]}")
        if base in mi.import_modules and len(rest) == 1:
            target = self._module_for(mi.import_modules[base])
            if target:
                return target.functions.get(rest[0])
        if base in mi.import_names and len(rest) == 1:
            modpath, orig = mi.import_names[base]
            # `from repro.models import transformer as T` -> T.lm_prefill
            target = self._module_for(f"{modpath}.{orig}")
            if target:
                return target.functions.get(rest[0])
            # `from x import Cls` -> Cls.method
            target = self._module_for(modpath)
            if target and orig in target.classes:
                return target.functions.get(f"{orig}.{rest[0]}")
        if base in mi.classes and len(rest) == 1:
            return mi.functions.get(f"{base}.{rest[0]}")
        return None

    def _module_for(self, modpath: str) -> Optional[ModuleIndex]:
        return self._by_dotted.get(modpath)

    def is_jit_wrapped_call(self, mi: ModuleIndex, head: str) -> bool:
        """True if `head` names a value produced by jax.jit(...)."""
        last = head.split(".")[-1]
        return last in mi.jit_wrapped_names

    # -- reachability -----------------------------------------------------
    def traced_functions(self) -> Set[FunctionInfo]:
        """Every function reachable from a trace root (roots included)."""
        work = [fi for mi in self.modules.values()
                for fi in mi.functions.values() if fi.is_root]
        seen: Set[int] = set()
        out: Set[FunctionInfo] = set()
        while work:
            fi = work.pop()
            if id(fi) in seen:
                continue
            seen.add(id(fi))
            out.add(fi)
            for head, _ in fi.calls:
                callee = self.resolve(fi.module, fi, head)
                if callee is not None and id(callee) not in seen:
                    work.append(callee)
        return out
