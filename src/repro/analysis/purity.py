"""Rule family 1: JAX purity / tracing / PRNG discipline.

Applies to every function reachable from a trace entry point
(``jax.jit`` / ``shard_map`` / ``pl.pallas_call`` — see
``repro.analysis.callgraph``):

* ``jax-host-time``   — ``time.time()`` and friends freeze at trace time.
* ``jax-host-random`` — ``np.random`` / stdlib ``random`` is invisible to
  jax's functional PRNG: the draw happens once, at trace time.
* ``jax-host-sync``   — ``.item()`` / ``float(x)`` / ``np.asarray(x)`` on
  a traced value either aborts tracing (ConcretizationTypeError) or, on
  values threaded out of the region, forces a device round-trip.
* ``prng-constant-key`` — ``jax.random.PRNGKey(<literal>)`` inside traced
  code: every trace re-derives the same stream.  Keys must enter as
  parameters or derive via ``split`` / ``fold_in``.
* ``prng-key-reuse``  — the same key variable fed to two sampling calls
  yields bit-identical draws; re-split between uses.

One rule deliberately reaches OUTSIDE traced code:

* ``jax-blocking-sync`` — ``float(x)`` / ``x.item()`` where ``x`` was
  just returned by a jitted callable.  Legal, but it blocks the host on
  device compute at that exact line; hot paths should defer the
  materialization (store the device value, convert when observed).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.callgraph import FunctionInfo, TreeIndex, dotted
from repro.analysis.findings import Finding

#: jax.random samplers that CONSUME a key (first positional argument).
SAMPLERS = {
    "normal", "uniform", "choice", "bernoulli", "categorical",
    "permutation", "randint", "truncated_normal", "gumbel",
    "exponential", "poisson", "gamma", "beta", "laplace", "rademacher",
    "bits", "ball", "dirichlet",
}
#: key DERIVATIONS — consume a key but return fresh ones; not "reuse".
DERIVERS = {"split", "fold_in", "clone", "key_data", "wrap_key_data"}

_TIME_CALLS = {"time.time", "time.perf_counter", "time.monotonic",
               "time.process_time", "time.time_ns",
               "datetime.datetime.now", "datetime.datetime.utcnow"}


def _numpy_aliases(mi) -> Set[str]:
    out = {alias for alias, mod in mi.import_modules.items()
           if mod in ("numpy", "np")}
    return out or {"np", "numpy"}


def _stdlib_random_aliases(mi) -> Set[str]:
    return {alias for alias, mod in mi.import_modules.items()
            if mod == "random"}


def _jax_random_heads(mi) -> Set[str]:
    """Dotted prefixes that mean jax.random in this module."""
    heads = {"jax.random"}
    for alias, mod in mi.import_modules.items():
        if mod == "jax":
            heads.add(f"{alias}.random")
        if mod == "jax.random":
            heads.add(alias)
    for local, (modpath, orig) in mi.import_names.items():
        if modpath == "jax" and orig == "random":
            heads.add(local)
    return heads


def _finding(fi: FunctionInfo, rule: str, line: int, msg: str) -> Finding:
    src_lines = fi.module.source.splitlines()
    text = src_lines[line - 1].strip() if 0 < line <= len(src_lines) else ""
    return Finding(rule=rule, path=fi.module.rel, line=line, message=msg,
                   symbol=fi.qualname, source=text)


def _check_traced_function(fi: FunctionInfo) -> List[Finding]:
    mi = fi.module
    np_aliases = _numpy_aliases(mi)
    rnd_aliases = _stdlib_random_aliases(mi)
    jr_heads = _jax_random_heads(mi)
    findings: List[Finding] = []
    # static argnames are concrete Python values at trace time — a
    # float()/np.asarray() on them is not a host sync
    static = fi.static_argnames

    # linear scan in source order so reassignments reset key tracking
    calls = [n for n in ast.walk(fi.node) if isinstance(n, ast.Call)]
    calls.sort(key=lambda n: (n.lineno, n.col_offset))
    assigns = [n for n in ast.walk(fi.node)
               if isinstance(n, (ast.Assign, ast.AugAssign))]
    # name -> line of last sampler use (for prng-key-reuse)
    key_used_at: Dict[str, int] = {}

    def reset_names_assigned_before(line: int) -> None:
        for a in assigns:
            if a.lineno <= line:
                targets = (a.targets if isinstance(a, ast.Assign)
                           else [a.target])
                for t in targets:
                    for sub in ast.walk(t):
                        if isinstance(sub, ast.Name):
                            key_used_at.pop(sub.id, None)

    last_seen_line = 0
    for call in calls:
        head = dotted(call.func)
        line = call.lineno

        # host clocks
        if head in _TIME_CALLS or (head and head.split(".")[-1] in
                                   ("time", "perf_counter", "monotonic")
                                   and head.split(".")[0] == "time"):
            findings.append(_finding(
                fi, "jax-host-time", line,
                f"'{head}()' in traced code — the clock value freezes at "
                f"trace time; thread timestamps in as arguments"))
            continue
        if head is None:
            continue
        parts = head.split(".")

        # host randomness: np.random.* / stdlib random.*
        if parts[0] in np_aliases and len(parts) >= 2 \
                and parts[1] == "random":
            findings.append(_finding(
                fi, "jax-host-random", line,
                f"'{head}()' in traced code — host RNG draws once at "
                f"trace time; use jax.random with an explicit key"))
            continue
        if parts[0] in rnd_aliases and len(parts) == 2:
            findings.append(_finding(
                fi, "jax-host-random", line,
                f"stdlib '{head}()' in traced code — use jax.random"))
            continue

        # host syncs on traced values
        if parts[-1] == "item" and len(parts) >= 2:
            findings.append(_finding(
                fi, "jax-host-sync", line,
                "'.item()' in traced code aborts tracing / syncs the "
                "device; keep the value on device"))
            continue
        if head == "float" and call.args \
                and not isinstance(call.args[0], ast.Constant) \
                and not (isinstance(call.args[0], ast.Name)
                         and call.args[0].id in static):
            findings.append(_finding(
                fi, "jax-host-sync", line,
                "'float(...)' on a traced value concretizes it; use "
                "jnp/astype inside traced code"))
            continue
        if parts[0] in np_aliases and parts[-1] == "asarray" \
                and not (call.args
                         and isinstance(call.args[0], ast.Name)
                         and call.args[0].id in static):
            findings.append(_finding(
                fi, "jax-host-sync", line,
                "'np.asarray(...)' in traced code pulls the value to "
                "host; use jnp.asarray"))
            continue

        # PRNG key discipline
        jr_parent = ".".join(parts[:-1])
        if jr_parent in jr_heads and parts[-1] == "PRNGKey":
            findings.append(_finding(
                fi, "prng-constant-key", line,
                "PRNGKey(...) constructed inside traced code — every "
                "trace re-derives the same stream; pass the key in as a "
                "parameter (or derive it via split/fold_in)"))
            continue
        if jr_parent in jr_heads and parts[-1] in SAMPLERS:
            reset_names_assigned_before(max(last_seen_line, 0))
            last_seen_line = line
            if call.args and isinstance(call.args[0], ast.Name):
                name = call.args[0].id
                # a reassignment between the two uses clears the name
                for a in assigns:
                    if key_used_at.get(name, 0) < a.lineno <= line:
                        targets = (a.targets if isinstance(a, ast.Assign)
                                   else [a.target])
                        for t in targets:
                            for sub in ast.walk(t):
                                if isinstance(sub, ast.Name) \
                                        and sub.id == name:
                                    key_used_at.pop(name, None)
                if name in key_used_at:
                    findings.append(_finding(
                        fi, "prng-key-reuse", line,
                        f"key '{name}' already consumed by a sampler at "
                        f"line {key_used_at[name]} — identical streams; "
                        f"split or fold_in between uses"))
                key_used_at[name] = line
            # constant key fed straight into a sampler
            if call.args and isinstance(call.args[0], ast.Call):
                inner = dotted(call.args[0].func)
                if inner and inner.split(".")[-1] == "PRNGKey":
                    findings.append(_finding(
                        fi, "prng-constant-key", line,
                        "sampler fed a literal PRNGKey(...) — the key "
                        "must originate from a parameter or split/"
                        "fold_in"))
    return findings


def _check_blocking_sync(fi: FunctionInfo, tree: TreeIndex) -> List[Finding]:
    """float(x)/.item() on names assigned from jitted calls (any code)."""
    mi = fi.module
    jit_results: Dict[str, int] = {}       # name -> assignment line
    findings: List[Finding] = []

    def flag_call(node: ast.Call) -> None:
        head = dotted(node.func)
        if head == "float" and node.args \
                and isinstance(node.args[0], ast.Name) \
                and node.args[0].id in jit_results:
            findings.append(_finding(
                fi, "jax-blocking-sync", node.lineno,
                f"float({node.args[0].id}) blocks on the jitted call "
                f"at line {jit_results[node.args[0].id]}; defer the "
                f"host sync (store the device value, materialize "
                f"when observed)"))
        elif head and head.split(".")[-1] == "item" \
                and len(head.split(".")) == 2 \
                and head.split(".")[0] in jit_results:
            name = head.split(".")[0]
            findings.append(_finding(
                fi, "jax-blocking-sync", node.lineno,
                f"{name}.item() blocks on the jitted call at line "
                f"{jit_results[name]}; defer the host sync"))

    stmts = [n for n in ast.walk(fi.node)
             if isinstance(n, (ast.Assign, ast.Call))]
    stmts.sort(key=lambda n: (n.lineno, n.col_offset))
    # calls that are the RHS of an assignment are handled inside the
    # Assign branch (RHS evaluates before the binding), not standalone
    assign_rhs = {id(n.value) for n in stmts if isinstance(n, ast.Assign)}
    for node in stmts:
        if isinstance(node, ast.Assign):
            if isinstance(node.value, ast.Call):
                flag_call(node.value)
            jitted = False
            if isinstance(node.value, ast.Call):
                head = dotted(node.value.func)
                callee = tree.resolve(mi, fi, head) if head else None
                jitted = ((callee is not None and callee.is_root)
                          or bool(head
                                  and tree.is_jit_wrapped_call(mi, head)))
            for tgt in node.targets:
                names = ([tgt] if isinstance(tgt, ast.Name)
                         else [e for e in getattr(tgt, "elts", [])
                               if isinstance(e, ast.Name)])
                for n in names:
                    if jitted:
                        jit_results[n.id] = node.lineno
                    else:
                        jit_results.pop(n.id, None)
        elif isinstance(node, ast.Call) and id(node) not in assign_rhs:
            flag_call(node)
    return findings


def check(tree: TreeIndex) -> List[Finding]:
    findings: List[Finding] = []
    traced = tree.traced_functions()
    for fi in sorted(traced, key=lambda f: (f.module.rel, f.qualname)):
        findings.extend(_check_traced_function(fi))
    traced_ids = {id(f) for f in traced}
    for mi in tree.modules.values():
        for fi in mi.functions.values():
            if id(fi) not in traced_ids:
                findings.extend(_check_blocking_sync(fi, tree))
    return findings
