"""repro-lint: JAX-aware static analysis for this tree.

Three rule families over the codebase's correctness-critical layers —
none of which a conventional linter can see:

1. **Purity / tracing** (``repro.analysis.purity``) — host clocks, host
   RNG, host syncs, and PRNG-key discipline inside any function
   reachable from a ``jax.jit`` / ``shard_map`` / ``pl.pallas_call``
   entry point.
2. **Pallas kernel discipline** (``repro.analysis.pallas_rules``) —
   every kernel wrapper plumbs ``interpret=``, declares its block sizes
   static, and has a same-named pure-jnp oracle in ``ref.py``.
3. **Lock discipline** (``repro.analysis.locks``) — ``# guarded-by:``
   annotated attributes only mutate under their lock, and the static
   lock-acquisition graph is cycle-free.  The runtime counterpart is
   :mod:`repro.analysis.watchdog`.

Run it as ``python scripts/lint.py``, ``python -m repro.analysis``, or
the ``repro-lint`` entry point; see docs/ANALYSIS.md for the rule
catalog, suppressions, and baseline workflow.
"""

from repro.analysis.findings import Finding, RULES
from repro.analysis.runner import analyze_paths, main
from repro.analysis.watchdog import (LockOrderError, OrderedLock,
                                     SERVING_LOCK_ORDER, instrument)

__all__ = ["Finding", "RULES", "analyze_paths", "main", "LockOrderError",
           "OrderedLock", "SERVING_LOCK_ORDER", "instrument"]
