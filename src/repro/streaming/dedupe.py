"""SolveDeduper — cross-tenant background-solve dedupe.

Demo and test fleets routinely run sibling tenants whose embedding
tables are byte-identical (same synthetic generator, same round), and
real fleets shard one model family across tenants that ingest the same
client population.  Each table's content fingerprint
(``CohortEngine.fingerprint``) keys a registry: the first tenant to warm
a fingerprint computes the ``PreparedSolve``; siblings wait on the
ticket's event and adopt the finished solve via
``CohortEngine.publish(prep, count=False)`` — ``count=False`` keeps
"exactly one engine solve per fingerprint" true on dashboards, which is
what the dedupe tests pin down.

The adopted ``PreparedSolve`` is shared by reference.  That is safe for
the serving path because everything downstream treats result arrays as
read-only (``CohortServer`` hands cohort draws out as python lists and
the engine cache replays defensive copies), and the engine state arrays
it installs (landmarks, eigenbases) are only ever read by later solves.

Threading: registry + done-cache are guarded by ``_dedupe_lock`` (ranked
in ``SERVING_LOCK_ORDER``).  Waiters block on a per-ticket Event with no
lock held.  A failed solve aborts its ticket so waiters fall back to
solving solo rather than hanging.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Optional, Tuple

__all__ = ["SolveDeduper"]

_WAIT_S = 30.0   # waiter back-stop; an eigensolve should never take this


class _Ticket:
    def __init__(self, fingerprint: bytes):
        self.fingerprint = fingerprint
        self.done = threading.Event()


class SolveDeduper:
    """Fingerprint-keyed registry of in-flight and finished solves."""

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be >= 1")
        self._capacity = capacity
        self._dedupe_lock = threading.Lock()
        self._inflight: dict = {}      # fp -> _Ticket; guarded-by: _dedupe_lock
        # fp -> PreparedSolve, LRU-bounded so long-gone tables don't pin
        # their (N, k) embeddings forever
        self._done: OrderedDict = OrderedDict()  # guarded-by: _dedupe_lock
        self.stats = {"leads": 0, "hits": 0, "waits": 0,
                      "aborts": 0}     # guarded-by: _dedupe_lock

    def begin(self, fingerprint: bytes) -> Tuple[Optional[_Ticket], object]:
        """Claim or join the solve for ``fingerprint``.

        Returns ``(ticket, prep)``:

        * ``(ticket, None)`` — caller leads: solve, then
          :meth:`complete` (or :meth:`abort` on failure).
        * ``(None, prep)`` — another tenant already solved it; adopt.
        * ``(None, None)`` — an in-flight lead aborted (or timed out);
          caller should solve solo without registering.
        """
        with self._dedupe_lock:
            prep = self._done.get(fingerprint)
            if prep is not None:
                self._done.move_to_end(fingerprint)
                self.stats["hits"] += 1
                return None, prep
            ticket = self._inflight.get(fingerprint)
            if ticket is None:
                ticket = _Ticket(fingerprint)
                self._inflight[fingerprint] = ticket
                self.stats["leads"] += 1
                return ticket, None
            self.stats["waits"] += 1
        ticket.done.wait(timeout=_WAIT_S)
        with self._dedupe_lock:
            prep = self._done.get(fingerprint)
            if prep is not None:
                self._done.move_to_end(fingerprint)
                self.stats["hits"] += 1
            return None, prep

    def complete(self, ticket: _Ticket, prep) -> None:
        """Publish the lead's finished solve and release waiters."""
        fp = ticket.fingerprint
        with self._dedupe_lock:
            self._done[fp] = prep
            self._done.move_to_end(fp)
            while len(self._done) > self._capacity:
                self._done.popitem(last=False)
            self._inflight.pop(fp, None)
        ticket.done.set()

    def abort(self, ticket: _Ticket) -> None:
        """Lead failed: release waiters with nothing (they solve solo)."""
        with self._dedupe_lock:
            self._inflight.pop(ticket.fingerprint, None)
            self.stats["aborts"] += 1
        ticket.done.set()
