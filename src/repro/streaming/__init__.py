"""Streaming re-cluster subsystem — serve v while warming v+1.

Under continuous embedding churn (clients report fresh embeddings every
round) the pre-streaming serving stack pays a full Nyström + eigensolve
inline on the first ``select_cohort`` after every ``update_embeddings``,
so p99 select latency degrades to cold-solve latency.  This package
makes re-clustering asynchronous and double-buffered:

* :class:`BackgroundSolver` (``solver.py``) — a small thread pool with a
  latest-wins dirty set.  ``CohortServer.update_embeddings`` submits a
  warm task; the worker snapshots the table, runs
  ``CohortEngine.prepare`` (which never touches serving-visible caches),
  and parks the finished ``(version, table, result)`` in the server's
  publish mailbox.  The serving path swaps the warmed result in
  atomically — selects never block on a solve after warm-up.  A bounded
  staleness knob (``StreamingSpec.max_stale_versions``) forces an inline
  solve only when the served version falls too far behind the table.
* :class:`AdmissionController` (``admission.py``) — per-tenant bounded
  queue depth + token-bucket rate limiting with typed :class:`ShedError`
  shedding, so one misbehaving tenant can't starve the others.
* :class:`SolveDeduper` (``dedupe.py``) — cross-tenant solve dedupe:
  tenants whose embedding tables share a content fingerprint ride one
  background solve, the rest adopt it via
  ``CohortEngine.publish(prep, count=False)``.

Wiring lives in ``launch/serve.py`` (swap protocol + streaming counters)
and ``launch/frontend.py`` (per-tenant :class:`StreamingSpec`, graceful
``close()``).  All locks introduced here are ranked in
``repro.analysis.watchdog.SERVING_LOCK_ORDER``; see
docs/ARCHITECTURE.md ("Streaming re-clustering") for the swap diagram.
"""

from repro.streaming.admission import (AdmissionController, QueueFullError,
                                       RateLimitError, ServiceClosedError,
                                       ShedError)
from repro.streaming.dedupe import SolveDeduper
from repro.streaming.solver import BackgroundSolver, StreamingSpec

__all__ = [
    "AdmissionController", "BackgroundSolver", "QueueFullError",
    "RateLimitError", "ServiceClosedError", "ShedError", "SolveDeduper",
    "StreamingSpec",
]
