"""AdmissionController — per-tenant select admission + load shedding.

Two independent gates, both optional:

* **bounded queue depth** — at most ``max_queue_depth`` selects in
  flight per tenant; the next admit sheds with :class:`QueueFullError`.
  Depth is the frontend's in-flight count (admit on entry, release in a
  ``finally``), so a tenant whose solves stall can only ever pin
  ``max_queue_depth`` worker threads, not the whole pool.
* **token bucket** — sustained ``rate_per_s`` with ``burst`` headroom;
  an empty bucket sheds with :class:`RateLimitError`.  Tokens accrue
  continuously from a monotonic clock (injectable for tests).

Shedding is deterministic — admit/shed depends only on current depth
and bucket level, never on timing races — which is what the streaming
tests pin down.  Both error types derive from :class:`ShedError` so
callers can catch one type and read ``.tenant`` for attribution.

Threading: all mutable state is guarded by ``_admission_lock``, ranked
innermost-but-one in ``SERVING_LOCK_ORDER`` (only ``_stats_lock`` ranks
later); ``try_admit``/``release`` are safe from any frontend worker.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional

__all__ = ["AdmissionController", "QueueFullError", "RateLimitError",
           "ServiceClosedError", "ShedError"]


class ServiceClosedError(RuntimeError):
    """Select arrived after ``close()``; the service is draining/down."""


class ShedError(RuntimeError):
    """A select was shed by admission control (load, not failure)."""

    def __init__(self, message: str, *, tenant: str = ""):
        super().__init__(message)
        self.tenant = tenant


class QueueFullError(ShedError):
    """Per-tenant in-flight depth is at ``max_queue_depth``."""


class RateLimitError(ShedError):
    """Per-tenant token bucket is empty (rate_per_s exceeded)."""


class AdmissionController:
    """Bounded-depth + token-bucket admission for one tenant."""

    def __init__(self, *, max_queue_depth: Optional[int] = None,
                 rate_per_s: Optional[float] = None,
                 burst: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic,
                 name: str = ""):
        if max_queue_depth is not None and max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth={max_queue_depth} must be >= 1")
        if rate_per_s is not None and rate_per_s <= 0:
            raise ValueError(f"rate_per_s={rate_per_s} must be > 0")
        self.name = name
        self.max_queue_depth = max_queue_depth
        self.rate_per_s = rate_per_s
        # default burst: one second's worth of tokens, at least 1
        self.burst = (burst if burst is not None
                      else max(1, int(rate_per_s)) if rate_per_s else None)
        self._clock = clock
        self._admission_lock = threading.Lock()
        self._depth = 0                      # guarded-by: _admission_lock
        self._tokens = float(self.burst or 0)   # guarded-by: _admission_lock
        self._last_refill = clock()          # guarded-by: _admission_lock
        self._counters = {"admitted": 0, "shed_queue": 0,
                          "shed_rate": 0}    # guarded-by: _admission_lock

    def try_admit(self) -> None:
        """Admit one select or raise a :class:`ShedError` subclass.

        On success the caller owns one unit of depth and MUST pair this
        with :meth:`release` (use ``finally``).
        """
        with self._admission_lock:
            if (self.max_queue_depth is not None
                    and self._depth >= self.max_queue_depth):
                self._counters["shed_queue"] += 1
                raise QueueFullError(
                    f"tenant {self.name!r}: {self._depth} selects in "
                    f"flight (max_queue_depth={self.max_queue_depth})",
                    tenant=self.name)
            if self.rate_per_s is not None:
                now = self._clock()
                self._tokens = min(
                    float(self.burst),
                    self._tokens + (now - self._last_refill)
                    * self.rate_per_s)
                self._last_refill = now
                if self._tokens < 1.0:
                    self._counters["shed_rate"] += 1
                    raise RateLimitError(
                        f"tenant {self.name!r}: token bucket empty "
                        f"(rate_per_s={self.rate_per_s}, "
                        f"burst={self.burst})", tenant=self.name)
                self._tokens -= 1.0
            self._depth += 1
            self._counters["admitted"] += 1

    def release(self) -> None:
        """Return one unit of depth admitted by :meth:`try_admit`."""
        with self._admission_lock:
            if self._depth <= 0:
                raise RuntimeError("release() without matching try_admit()")
            self._depth -= 1

    @property
    def depth(self) -> int:
        with self._admission_lock:
            return self._depth

    def stats(self) -> dict:
        with self._admission_lock:
            out = dict(self._counters)
            out["depth"] = self._depth
        return out
