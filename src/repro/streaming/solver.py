"""BackgroundSolver — the double-buffer's write side.

A small thread pool that runs solve-ahead ("warm") tasks off the serving
path.  The queue is a **latest-wins dirty set** keyed by tenant: ten
rapid ``update_embeddings`` calls on one tenant coalesce into one
pending warm task, because the task itself snapshots the freshest table
when it finally runs — warming ten intermediate versions would be wasted
work.  Tasks for *different* keys run concurrently (up to ``workers``).

The solver knows nothing about engines or tables; it runs opaque
callables.  ``CohortServer._background_warm`` is the canonical task: it
snapshots, ``CohortEngine.prepare``-s, and parks the result in the
server's publish mailbox for the next select to swap in.

Threading: ``_dirty``/``_inflight``/``_closed``/``stats`` are guarded by
``_queue_lock`` (ranked in ``SERVING_LOCK_ORDER``); workers run tasks
with no solver lock held, so a slow solve never blocks ``submit``.  The
wake signal is a plain :class:`threading.Event` rather than a Condition
so the runtime lock-order watchdog can instrument ``_queue_lock`` like
any other lock.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import OrderedDict
from typing import Callable, Optional

__all__ = ["BackgroundSolver", "StreamingSpec"]

#: nice value for worker threads; see :func:`_deprioritize`.
_WORKER_NICENESS = 10


def _deprioritize() -> None:
    """Drop the calling worker thread's scheduling priority.

    A solve is tens of milliseconds of compute; a warmed select is ~2.
    At equal priority on a loaded (or single-core) host the solver
    starves concurrent selects — the classic compaction-vs-reads
    problem, solved the classic way: background threads run niced, so
    the scheduler hands the core back the moment a select thread wakes.
    On Linux ``setpriority(PRIO_PROCESS, 0, ...)`` is per-thread;
    elsewhere it may be process-wide or unsupported, so best-effort.
    """
    try:
        os.setpriority(os.PRIO_PROCESS, 0, _WORKER_NICENESS)
    except (AttributeError, OSError):
        pass


@dataclasses.dataclass(frozen=True)
class StreamingSpec:
    """Per-tenant streaming-serving knobs (see package docstring).

    max_stale_versions — serve a warmed result as long as the table
                         version it was solved at is within this many
                         versions of the current table; beyond it the
                         select solves inline (bounded staleness).
                         ``None`` never forces an inline solve: selects
                         serve whatever is warmed, however old.
    solver_workers     — background solve threads (shared pool when the
                         frontend owns the solver).
    dedupe             — ride another tenant's solve when the embedding
                         tables share a content fingerprint.
    max_queue_depth    — admission: max concurrent selects per tenant
                         before ``QueueFullError`` sheds.  None = no cap.
    rate_per_s/burst   — admission: token-bucket select rate limit.
                         None = unlimited.
    """
    max_stale_versions: Optional[int] = None
    solver_workers: int = 1
    dedupe: bool = True
    max_queue_depth: Optional[int] = 64
    rate_per_s: Optional[float] = None
    burst: Optional[int] = None

    def __post_init__(self):
        if self.max_stale_versions is not None and self.max_stale_versions < 0:
            raise ValueError(
                f"max_stale_versions={self.max_stale_versions} must be >= 0")
        if self.solver_workers < 1:
            raise ValueError(
                f"solver_workers={self.solver_workers} must be >= 1")


class BackgroundSolver:
    """Latest-wins background task pool for solve-ahead work."""

    def __init__(self, workers: int = 1, *, name: str = "repro-solver"):
        if workers < 1:
            raise ValueError(f"workers={workers} must be >= 1")
        self._queue_lock = threading.Lock()
        self._wake = threading.Event()
        # key -> task; latest submit for a key replaces the pending one
        self._dirty: "OrderedDict[object, Callable[[], None]]" = \
            OrderedDict()               # guarded-by: _queue_lock
        self._inflight: set = set()     # guarded-by: _queue_lock
        self._closed = False            # guarded-by: _queue_lock
        self.stats = {"submitted": 0, "runs": 0, "errors": 0,
                      "coalesced": 0}   # guarded-by: _queue_lock
        self._threads = [
            threading.Thread(target=self._loop, name=f"{name}-{i}",
                             daemon=True)
            for i in range(workers)]
        for t in self._threads:
            t.start()

    def submit(self, key, fn: Callable[[], None]) -> bool:
        """Mark ``key`` dirty; ``fn`` runs on a worker soon.

        Returns False (and drops the task) after :meth:`close` — a
        server racing a shutdown must treat that as "no warm coming".
        """
        with self._queue_lock:
            if self._closed:
                return False
            self.stats["submitted"] += 1
            if key in self._dirty:
                self.stats["coalesced"] += 1
            self._dirty[key] = fn
            self._dirty.move_to_end(key)
        self._wake.set()
        return True

    def _next_task(self):
        with self._queue_lock:
            for key, fn in self._dirty.items():
                # one in-flight task per key: the task snapshots the
                # freshest table itself, so running two generations of
                # the same tenant concurrently is pure waste
                if key not in self._inflight:
                    del self._dirty[key]
                    self._inflight.add(key)
                    return key, fn
            # nothing runnable (empty, or every dirty key already in
            # flight): clear under the lock — submit inserts under the
            # same lock before set(), and task completion re-sets the
            # event after discard, so a wake can't be lost
            self._wake.clear()
            return None, None

    def _loop(self) -> None:
        _deprioritize()
        while True:
            with self._queue_lock:
                if self._closed and not self._dirty:
                    return
            key, fn = self._next_task()
            if fn is None:
                self._wake.wait(timeout=0.05)
                continue
            try:
                with self._queue_lock:
                    self.stats["runs"] += 1
                fn()
            except Exception:
                with self._queue_lock:
                    self.stats["errors"] += 1
            finally:
                with self._queue_lock:
                    self._inflight.discard(key)
                self._wake.set()   # another key may be runnable now

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until no task is pending or running.  True on success."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._queue_lock:
                idle = not self._dirty and not self._inflight
            if idle:
                return True
            if deadline is not None and time.monotonic() >= deadline:
                return False
            time.sleep(0.002)

    def close(self, timeout: Optional[float] = None) -> None:
        """Finish pending work, then stop and join the workers."""
        with self._queue_lock:
            self._closed = True
        self._wake.set()
        self.drain(timeout)
        self._wake.set()
        for t in self._threads:
            t.join(timeout=timeout)
