"""Policy-serving subsystem: the paper's Algorithm II, reusable.

``ClusterPolicy`` is the Deep-Q half of DQRE-SCnet (cluster-level
actions, ε-greedy cohort draws, replay + TD training) factored out of
the simulation-only ``DQREScSelection`` so the serving path
(``repro.launch.serve.CohortServer``) can run the learned policy online.
See docs/ARCHITECTURE.md ("The DQN policy loop") for the round-trip.
"""

from repro.policy.cluster_policy import ClusterPolicy

__all__ = ["ClusterPolicy"]
