"""Algorithm II as a reusable component: a Deep-Q policy over clusters.

The paper's hybrid loop is  *cluster the clients spectrally (Algorithm
I), then let a Deep-Q agent decide which clusters this round's cohort is
drawn from (Algorithm II)*.  Before this module existed, Algorithm II
lived inline in ``core/selection.DQREScSelection`` and could only run
inside a simulated :class:`repro.fed.FederatedRunner`; the serving path
(``launch/serve.CohortServer``) fell back to uniform stratified draws.

:class:`ClusterPolicy` extracts the DQN half into a state-agnostic
component shared by both callers:

* ``DQREScSelection`` feeds it the *simulation* state (global-model
  embedding ‖ cluster centroids) each round.
* ``CohortServer`` feeds it the *serving* state (per-cluster
  population / participation / reward statistics built by
  :func:`repro.fed.metrics.cluster_policy_state` — ``"basic"``/
  ``"rich"``, or ``"system"`` which adds the client-realism
  availability + latency EMAs from ``repro.fed.realism`` round
  outcomes) and trains it online from the accuracy signal of completed
  rounds.  Under a deadline the reward may be the deadline-blended
  shaping (:func:`repro.fed.realism.blended_reward`) instead of the
  pure accuracy signal.

The action space is the cluster index: one ε-greedy cluster choice per
cohort slot, so a round's recorded ``actions`` are the per-slot cluster
draws and the induced per-cluster draw weights are
``ε/k + (1-ε)·1[argmax Q]`` (see :meth:`ClusterPolicy.draw_weights`).
The reward is the paper's accuracy-delta signal
``Ξ^(acc − target) − 1`` (FAVOR shaping, §3.3), computed by the caller.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.dqn import DQNAgent, DQNConfig


class ClusterPolicy:
    """Deep-Q policy over ``num_clusters`` discrete cluster actions.

    Wraps a :class:`repro.core.dqn.DQNAgent` (current + target nets,
    uniform replay, ε-greedy) with the cohort-draw loop of Algorithm II.
    The policy is state-agnostic: callers build their own ``(state_dim,)``
    float32 state vectors and pass them to :meth:`draw` / :meth:`observe`.

    Args:
        num_clusters: size of the action space (k of Algorithm I).
        state_dim:    length of the caller's state vectors.
        seed:         PRNG seed for the Q-network init and the fallback rng.
        dqn_overrides: optional :class:`~repro.core.dqn.DQNConfig` field
            overrides (e.g. ``{"eps_decay_steps": 50, "hidden": (32,)}``).
        state_features: optional label of the state layout this policy
            was built for (``"basic"`` 3k+1 / ``"rich"`` 5k+1 /
            ``"system"`` 7k+1 of
            :func:`repro.fed.metrics.cluster_policy_state`).  Purely
            descriptive — reported by :meth:`stats` and echoed in the
            shape-mismatch error — the policy stays state-agnostic.
    """

    def __init__(self, num_clusters: int, state_dim: int, *, seed: int = 0,
                 dqn_overrides: Optional[dict] = None,
                 state_features: Optional[str] = None):
        self.num_clusters = num_clusters
        self.state_dim = state_dim
        self.state_features = state_features
        cfg = DQNConfig(state_dim=state_dim, num_actions=num_clusters,
                        **(dqn_overrides or {}))
        self.agent = DQNAgent(jax.random.PRNGKey(seed), cfg)
        self.rng = np.random.default_rng(seed)
        self._last_loss = 0.0              # device scalar after train()

    def _check_state(self, state_vec: np.ndarray, caller: str) -> np.ndarray:
        """Fail fast on a wrong-length state with a readable error.

        Without this, a mis-built state (e.g. per-cluster stats shorter
        than k, or a "rich" state fed to a policy built for "basic")
        only dies inside the Q-network's first matmul with an opaque
        shape message.
        """
        s = np.asarray(state_vec, np.float32).reshape(-1)
        if len(s) != self.state_dim:
            layout = (f" (policy built for state_features="
                      f"{self.state_features!r})" if self.state_features
                      else "")
            raise ValueError(
                f"ClusterPolicy.{caller}: state vector has length "
                f"{len(s)} but the policy expects state_dim="
                f"{self.state_dim}{layout}")
        return s

    # -- acting -----------------------------------------------------------
    def epsilon(self) -> float:
        """Current exploration rate of the underlying agent's schedule."""
        return self.agent.epsilon()

    def draw_weights(self, state_vec: np.ndarray) -> np.ndarray:
        """Expected per-cluster draw distribution at the current ε.

        Returns the (num_clusters,) marginal probability that a single
        cohort slot is drawn from each cluster, ignoring pool depletion:
        ``ε/k`` everywhere plus ``1-ε`` on the greedy (argmax-Q) cluster.
        Pure readout — does not advance the ε schedule.
        """
        q = self.agent.q_values(self._check_state(state_vec, "draw_weights"))
        k = self.num_clusters
        eps = self.agent.epsilon()
        w = np.full(k, eps / k, np.float64)
        w[int(np.argmax(q))] += 1.0 - eps
        return w

    def draw(self, rng: np.random.Generator, state_vec: np.ndarray,
             pools: Dict[int, List[int]], cohort_size: int,
             ) -> Tuple[List[int], List[int]]:
        """Draw a cohort: one ε-greedy cluster choice per slot.

        Args:
            rng:       caller's generator (shuffles pools + exploration).
            state_vec: (state_dim,) state the Q function scores.
            pools:     cluster id -> mutable list of member client ids;
                       drawn clients are popped (no replacement).  Keys
                       must cover ``range(num_clusters)``; empty lists
                       mark clusters with no members (e.g. above the
                       engine's eigengap k̂).
            cohort_size: number of clients to draw.

        Returns:
            ``(picked, actions)`` — client ids (≤ cohort_size if the
            pools run dry) and the cluster chosen for each slot.
            Advances the agent's ε schedule by one step.
        """
        self.agent.steps += 1
        q = self.agent.q_values(self._check_state(state_vec, "draw"))
        eps = self.agent.epsilon()
        for pool in pools.values():
            rng.shuffle(pool)
        order = np.argsort(-q)
        picked: List[int] = []
        actions: List[int] = []
        while len(picked) < cohort_size:
            if rng.random() < eps:
                c = int(rng.integers(self.num_clusters))
            else:
                c = int(next((c for c in order if pools[c]), order[0]))
            if not pools[c]:
                nonempty = [cc for cc in range(self.num_clusters)
                            if pools[cc]]
                if not nonempty:
                    break
                c = int(rng.choice(nonempty))
            picked.append(pools[c].pop())
            actions.append(c)
        return picked, actions

    # -- learning ---------------------------------------------------------
    def observe(self, state_vec: np.ndarray, actions: Sequence[int],
                reward: float, next_state_vec: np.ndarray) -> None:
        """Record one round: every slot's cluster choice shares the
        round's scalar reward (the paper credits all "rewarded users")."""
        s = self._check_state(state_vec, "observe")
        s2 = self._check_state(next_state_vec, "observe")
        for a in actions:
            self.agent.observe(s, int(a), reward, s2)

    def train(self, rng: Optional[np.random.Generator] = None):
        """One TD minibatch step; returns (and remembers) the loss.

        The return value is a DEVICE scalar — ``CohortServer`` calls
        this under its select lock, so forcing a host sync here would
        stall concurrent selects.  :attr:`last_loss` materializes it
        lazily when the stats endpoint asks.
        """
        self._last_loss = self.agent.train_step(
            rng if rng is not None else self.rng)
        return self._last_loss

    @property
    def last_loss(self) -> float:
        """Most recent TD loss, materialized on demand (syncs here)."""
        return float(self._last_loss)

    def stats(self) -> dict:
        """Serving-dashboard counters: ε, steps, replay fill, last loss."""
        buf = self.agent.buffer
        return {"epsilon": self.agent.epsilon(),
                "state_dim": self.state_dim,
                "state_features": self.state_features,
                "steps": self.agent.steps,
                "train_calls": self.agent.train_calls,
                "buffer_fill": buf.size / buf.capacity,
                "buffer_size": buf.size,
                "last_loss": self.last_loss}
