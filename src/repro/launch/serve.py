"""Serving launcher: batched prefill + decode with continuous batching.

Implements a small production-shaped server loop: a request queue, one
prefill step per admitted batch, then token-by-token decode with greedy or
temperature sampling.  Used by examples/serve_lm.py; the decode step is
exactly the one the dry-run lowers for decode_32k / long_500k.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --batch 4 --prompt-len 32 --gen-len 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    generated: Optional[List[int]] = None


class Server:
    """Batched static-shape server (prefill once, decode step-by-step)."""

    def __init__(self, cfg, batch: int, max_seq: int, *, seed: int = 0,
                 temperature: float = 0.0):
        import jax
        from repro.models import transformer as T

        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        self.temperature = temperature
        key = jax.random.PRNGKey(seed)
        self.params = T.init_lm(key, cfg)
        self._prefill = jax.jit(
            lambda p, b, c: T.lm_prefill(p, cfg, b, c))
        self._decode = jax.jit(
            lambda p, t, c, pos: T.lm_decode_step(p, cfg, t, c, pos))
        self._rng = np.random.default_rng(seed)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / self.temperature
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.array([self._rng.choice(len(row), p=row) for row in p],
                        np.int32)

    def serve_batch(self, requests: List[Request]) -> List[Request]:
        import jax.numpy as jnp
        from repro.models import transformer as T

        assert len(requests) <= self.batch
        while len(requests) < self.batch:                  # pad the batch
            requests = requests + [Request(-1, requests[0].prompt, 0)]
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, : len(r.prompt)] = r.prompt

        caches = T.init_lm_cache(self.cfg, self.batch, self.max_seq)
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)},
                                       caches)
        out = [[] for _ in requests]
        tok = self._sample(np.asarray(logits))
        steps = max(r.max_new_tokens for r in requests)
        t0 = time.time()
        for s in range(steps):
            for i, r in enumerate(requests):
                if s < r.max_new_tokens:
                    out[i].append(int(tok[i]))
            logits, caches = self._decode(self.params,
                                          jnp.asarray(tok[:, None]),
                                          caches, jnp.int32(plen + s))
            tok = self._sample(np.asarray(logits))
        dt = time.time() - t0
        self.last_decode_tok_s = self.batch * steps / max(dt, 1e-9)
        for r, gen in zip(requests, out):
            r.generated = gen
        return [r for r in requests if r.uid >= 0]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    server = Server(cfg, args.batch, args.prompt_len + args.gen_len,
                    temperature=args.temperature, seed=args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32),
                    args.gen_len)
            for i in range(args.batch)]
    t0 = time.time()
    done = server.serve_batch(reqs)
    print(f"served {len(done)} requests in {time.time()-t0:.1f}s "
          f"({server.last_decode_tok_s:,.1f} decode tok/s)")
    for r in done[:2]:
        print(f"req {r.uid}: first 10 generated tokens {r.generated[:10]}")


if __name__ == "__main__":
    main()
