"""Serving launchers: the LM server loop and the cohort-selection service.

``Server`` implements a small production-shaped LM loop: a request
queue, one prefill step per admitted batch, then token-by-token decode
with greedy or temperature sampling.  Used by examples/serve_lm.py; the
decode step is exactly the one the dry-run lowers for decode_32k /
long_500k.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --batch 4 --prompt-len 32 --gen-len 32

``CohortServer`` is the federated control-plane counterpart: it owns the
live client-embedding table (versioned, copy-on-write, so embedding
updates never tear a concurrent selection) and a
``repro.cohort.CohortEngine``, and answers cohort requests either with a
cluster-stratified draw (``policy="stratified"``) or with the paper's
Algorithm II (``policy="dqn"``): a :class:`repro.policy.ClusterPolicy`
scores the clusters and draws the cohort ε-greedily, trained online from
the accuracy signal reported back via ``observe_round``.  Because the
engine warm-starts and fingerprint-caches between requests, steady-state
selection cost is dominated by the (N, m) cross-affinity — sharded over
the cohort mesh when more than one device is visible.  ``stats()``
exposes the whole serving picture: engine cache/warm/cold counters,
per-phase latencies, table version, and the policy's ε / replay fill.

  PYTHONPATH=src python -m repro.launch.serve --cohort 100000 \
      --cohort-size 64 --landmarks kmeans++ --policy dqn --rounds 5
"""

from __future__ import annotations

import argparse
import dataclasses
import threading
import time
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    generated: Optional[List[int]] = None


class Server:
    """Batched static-shape server (prefill once, decode step-by-step)."""

    def __init__(self, cfg, batch: int, max_seq: int, *, seed: int = 0,
                 temperature: float = 0.0):
        import jax
        from repro.models import transformer as T

        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        self.temperature = temperature
        key = jax.random.PRNGKey(seed)
        self.params = T.init_lm(key, cfg)
        self._prefill = jax.jit(
            lambda p, b, c: T.lm_prefill(p, cfg, b, c))
        self._decode = jax.jit(
            lambda p, t, c, pos: T.lm_decode_step(p, cfg, t, c, pos))
        self._rng = np.random.default_rng(seed)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / self.temperature
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.array([self._rng.choice(len(row), p=row) for row in p],
                        np.int32)

    def serve_batch(self, requests: List[Request]) -> List[Request]:
        import jax.numpy as jnp
        from repro.models import transformer as T

        assert len(requests) <= self.batch
        while len(requests) < self.batch:                  # pad the batch
            requests = requests + [Request(-1, requests[0].prompt, 0)]
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, : len(r.prompt)] = r.prompt

        caches = T.init_lm_cache(self.cfg, self.batch, self.max_seq)
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)},
                                       caches)
        out = [[] for _ in requests]
        tok = self._sample(np.asarray(logits))
        steps = max(r.max_new_tokens for r in requests)
        t0 = time.time()
        for s in range(steps):
            for i, r in enumerate(requests):
                if s < r.max_new_tokens:
                    out[i].append(int(tok[i]))
            logits, caches = self._decode(self.params,
                                          jnp.asarray(tok[:, None]),
                                          caches, jnp.int32(plen + s))
            tok = self._sample(np.asarray(logits))
        dt = time.time() - t0
        self.last_decode_tok_s = self.batch * steps / max(dt, 1e-9)
        for r, gen in zip(requests, out):
            r.generated = gen
        return [r for r in requests if r.uid >= 0]


#: smoothing factor for the server's per-phase latency EMAs.
_LATENCY_EMA = 0.2
#: smoothing factor for the per-cluster reward EMAs in the policy state
#: (independent knob from the latency smoothing; they just share a value).
_REWARD_EMA = 0.2


class CohortServer:
    """Cohort-selection service backed by a :class:`CohortEngine`.

    Holds the latest (N, d) client-embedding table (updated as client
    deltas stream in via ``update_embeddings``) and serves
    ``select_cohort(size)`` requests: the engine clusters the table —
    dense, Nyström, or mesh-sharded Nyström depending on N and devices —
    and the cohort is drawn from the clusters by the configured policy:

    * ``policy="stratified"`` — round-robin across clusters, the
      uniform de-biasing draw.
    * ``policy="dqn"`` — the paper's Algorithm II: a
      :class:`repro.policy.ClusterPolicy` (cluster-level Deep-Q agent)
      chooses the cluster for every cohort slot ε-greedily; callers
      report each round's resulting accuracy via :meth:`observe_round`,
      which shapes the reward (FAVOR's ``Ξ^(acc − target) − 1``),
      updates the replay buffer, and takes one TD training step — the
      policy learns online which clusters to favor while serving.

    Concurrency: the embedding table is **versioned copy-on-write** —
    ``update_embeddings`` builds a fresh table and swaps the reference
    under a writer lock, while ``select_cohort`` snapshots the current
    reference, so a selection in flight always clusters one internally
    consistent table (never a half-updated one).  Selections themselves
    are serialized on a second lock because the engine's warm-start
    state is single-writer.  Embedding updates only invalidate the
    engine's exact-match cache; small drift keeps the warm-start path,
    so steady-state request latency excludes landmark reselection and
    cold eigensolves.

    Args:
        num_clients:  N, rows of the embedding table.
        embed_dim:    d, embedding width.
        config:       :class:`repro.cohort.CohortConfig` for the engine.
        seed:         seeds the engine, the draw rng, and the Q-network.
        policy:       "stratified" | "dqn".
        target_accuracy: reward pivot for the DQN policy's shaping.
        dqn_overrides: DQNConfig field overrides for ``policy="dqn"``.
    """

    POLICIES = ("stratified", "dqn")

    def __init__(self, num_clients: int, embed_dim: int, *,
                 config=None, seed: int = 0, policy: str = "stratified",
                 target_accuracy: float = 0.85,
                 dqn_overrides: Optional[dict] = None):
        from repro.cohort import CohortConfig, CohortEngine

        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"expected one of {self.POLICIES}")
        self.config = config or CohortConfig()
        self.engine = CohortEngine(self.config, seed=seed)
        self.rng = np.random.default_rng(seed)
        self.policy_name = policy
        self.target_accuracy = target_accuracy
        k = self.config.num_clusters
        if policy == "dqn":
            from repro.policy import ClusterPolicy
            # serving state = cluster_policy_state(): 3 stats per
            # cluster (population / participation / reward EMA) + the
            # last reported global accuracy
            self.policy = ClusterPolicy(k, state_dim=3 * k + 1, seed=seed,
                                        dqn_overrides=dqn_overrides)
        else:
            self.policy = None

        table = np.zeros((num_clients, embed_dim), np.float32)
        table.setflags(write=False)       # snapshots must stay immutable
        self._snap = (0, table)           # (version, table), swapped whole
        self._write_lock = threading.Lock()
        self._select_lock = threading.Lock()

        self._participation = np.zeros(k, np.float64)
        self._reward_ema = np.zeros(k, np.float32)
        self.prev_accuracy = 0.0
        self._pending = None              # (state_vec, actions, assign)
        self._latency = {"solve_s": 0.0, "draw_s": 0.0, "total_s": 0.0}
        self._round_timings: dict = {}    # running means per phase
        self._counters = {"requests": 0, "updates": 0, "rounds_observed": 0,
                          "dropped_transitions": 0}
        self.last_select_s = 0.0

    # -- embedding table (versioned copy-on-write) -----------------------
    @property
    def embeds(self) -> np.ndarray:
        """Current (read-only) embedding-table snapshot."""
        return self._snap[1]

    @property
    def version(self) -> int:
        """Table version; bumps on every ``update_embeddings``."""
        return self._snap[0]

    def snapshot(self):
        """Atomically read ``(version, table)``; the table is immutable."""
        # the (version, table) pair is swapped as one tuple, so a single
        # reference read can never pair a stale version with a new table
        return self._snap

    def update_embeddings(self, client_ids, new_embeds) -> None:
        """Replace the embedding rows of ``client_ids``.

        Copy-on-write: readers holding the previous snapshot are
        unaffected; the new (version, table) pair becomes visible
        atomically.
        """
        ids = np.asarray(client_ids)
        rows = np.asarray(new_embeds, np.float32)
        with self._write_lock:
            version, table = self._snap
            table = table.copy()
            table[ids] = rows
            table.setflags(write=False)
            self._snap = (version + 1, table)
            self._counters["updates"] += 1

    # -- serving ----------------------------------------------------------
    def _ema(self, name: str, value: float) -> None:
        prev = self._latency[name]
        self._latency[name] = (value if self._counters["requests"] == 0
                               else prev + _LATENCY_EMA * (value - prev))

    def _policy_state(self, assign: np.ndarray) -> np.ndarray:
        from repro.fed.metrics import cluster_policy_state
        return cluster_policy_state(assign, self.config.num_clusters,
                                    self._participation, self._reward_ema,
                                    self.prev_accuracy)

    def select_cohort(self, cohort_size: int):
        """Serve one cohort; returns ``(client_ids, CohortResult)``.

        ``client_ids`` has ``cohort_size`` entries unless the table has
        fewer clients.  With ``policy="dqn"`` the draw's (state,
        actions) pair is parked until :meth:`observe_round` reports the
        round's accuracy.
        """
        with self._select_lock:
            t0 = time.perf_counter()
            _, table = self.snapshot()
            res = self.engine.select(table)
            t_solve = time.perf_counter()
            k = self.config.num_clusters
            pools = {c: list(np.flatnonzero(res.assign == c))
                     for c in range(k)}
            if self.policy is not None:
                state = self._policy_state(res.assign)
                picked, actions = self.policy.draw(
                    self.rng, state, pools, cohort_size)
                if self._pending is not None:
                    # the serve contract is select -> observe_round ->
                    # select; a second select before the round report
                    # replaces the parked transition, and the earlier
                    # draw is never learned from — count it so the
                    # dashboard can see mis-sequenced callers
                    self._counters["dropped_transitions"] += 1
                self._pending = (state, actions, res.assign)
            else:
                for pool in pools.values():
                    self.rng.shuffle(pool)
                ordered = [pools[c] for c in range(res.k)]
                picked = []
                while len(picked) < cohort_size and any(ordered):
                    for pool in ordered:
                        if pool and len(picked) < cohort_size:
                            picked.append(pool.pop())
            picked = np.asarray(picked[:cohort_size], np.int64)
            if len(picked):
                np.add.at(self._participation, res.assign[picked], 1.0)
            t1 = time.perf_counter()
            self._ema("solve_s", t_solve - t0)
            self._ema("draw_s", t1 - t_solve)
            self._ema("total_s", t1 - t0)
            self._counters["requests"] += 1
            self.last_select_s = t1 - t0
            return picked, res

    def observe_round(self, accuracy: float, timings: Optional[dict] = None,
                      ) -> float:
        """Report a completed round back to the server; returns the reward.

        ``accuracy`` is the post-aggregation global-model accuracy of
        the round trained on the last served cohort; the reward is the
        paper's shaping ``Ξ^(acc − target) − 1``.  With ``policy="dqn"``
        this is the online learning step: the parked (state, actions)
        from :meth:`select_cohort` plus the new state go into the replay
        buffer and one TD minibatch runs.  ``timings`` (e.g.
        ``RoundResult.timings`` from ``repro.fed.rounds``) is folded
        into the per-phase running means reported by :meth:`stats`.
        """
        from repro.core.selection import favor_reward

        reward = favor_reward(accuracy, self.target_accuracy)
        # same lock as select_cohort: a racing selection must not park a
        # new (state, actions) transition between our read of _pending
        # and its clear, or that round's learning step would be dropped
        with self._select_lock:
            if self.policy is not None and self._pending is not None:
                state, actions, assign = self._pending
                for c in set(actions):
                    self._reward_ema[c] += _REWARD_EMA * (
                        reward - self._reward_ema[c])
                self.prev_accuracy = accuracy
                next_state = self._policy_state(assign)
                self.policy.observe(state, actions, reward, next_state)
                self.policy.train(self.rng)
                self._pending = None
            else:
                self.prev_accuracy = accuracy
            if timings:
                n = self._counters["rounds_observed"]
                for phase, seconds in timings.items():
                    prev = self._round_timings.get(phase, 0.0)
                    self._round_timings[phase] = (
                        prev + (seconds - prev) / (n + 1))
            self._counters["rounds_observed"] += 1
        return reward

    def stats(self) -> dict:
        """One dict for the serving dashboard: engine, latency, policy.

        Keys: ``requests`` / ``updates`` / ``rounds_observed`` /
        ``dropped_transitions`` counters (the last counts DQN draws
        replaced by a second ``select_cohort`` before their round was
        reported — mis-sequenced callers),
        ``table_version``, ``num_clients``, ``engine`` (cache hits,
        warm/cold starts, solves, autotuned ``auto_m`` when enabled),
        ``latency_s`` (EMA solve/draw/total), ``round_timings_s``
        (running means of ingested ``RoundResult.timings`` phases),
        ``last_select`` (method/source/drift/k of the latest solve), and
        ``policy`` (kind plus ε / steps / replay fill for "dqn").
        """
        last = self.engine.state.result
        policy = {"kind": self.policy_name}
        if self.policy is not None:
            policy.update(self.policy.stats())
        return {
            **dict(self._counters),
            "table_version": self.version,
            "num_clients": self.embeds.shape[0],
            "engine": dict(self.engine.stats),
            "latency_s": dict(self._latency),
            "round_timings_s": dict(self._round_timings),
            "last_select": None if last is None else {
                "method": last.method, "source": last.source,
                "drift": last.drift, "k": last.k,
                "seconds": last.seconds},
            "policy": policy,
        }


def _cohort_main(args) -> None:
    """Cohort-service demo loop: N synthetic clients, drifting embeddings.

    With ``--policy dqn`` the loop also synthesizes a reward signal:
    clients of true cluster 0 are "stale" (contribute nothing), so round
    accuracy rises with the fraction of the cohort drawn outside it —
    over a few dozen rounds the policy's draw weights visibly shift away
    from the engine cluster covering that group.
    """
    from repro.cohort import CohortConfig

    rng = np.random.default_rng(args.seed)
    d = 8
    centers = rng.normal(size=(args.num_clusters, d)).astype(np.float32) * 6
    assign_true = rng.integers(0, args.num_clusters, args.cohort)
    embeds = (centers[assign_true]
              + rng.normal(size=(args.cohort, d)).astype(np.float32))
    num_landmarks = args.num_landmarks
    if num_landmarks not in (None, "auto"):
        num_landmarks = int(num_landmarks)
    server = CohortServer(
        args.cohort, d, seed=args.seed, policy=args.policy,
        target_accuracy=0.85,
        config=CohortConfig(num_clusters=args.num_clusters,
                            landmarks=args.landmarks,
                            num_landmarks=num_landmarks))
    server.update_embeddings(np.arange(args.cohort), embeds)
    for r in range(args.rounds):
        ids, res = server.select_cohort(args.cohort_size)
        # synthetic round outcome: cohort quality = share of non-stale
        # clients (true cluster 0 is stale), reported back to the policy
        useful = float(np.mean(assign_true[ids] != 0)) if len(ids) else 0.0
        reward = server.observe_round(0.5 + 0.4 * useful)
        # the selected cohort trains and drifts; everyone else is static
        server.update_embeddings(
            ids, server.embeds[ids]
            + 0.01 * rng.normal(size=(len(ids), d)).astype(np.float32))
        print(f"round {r}: {len(ids)} clients from {res.k} clusters "
              f"({res.method}/{res.source}) in {server.last_select_s:.3f}s "
              f"({args.cohort / max(server.last_select_s, 1e-9):,.0f} "
              f"clients/s, reward {reward:+.3f})")
    import json
    print("server stats:", json.dumps(server.stats(), indent=2,
                                      default=float))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cohort", type=int, default=0, metavar="N",
                    help="serve cohort selection for N clients instead "
                         "of the LM loop")
    ap.add_argument("--cohort-size", type=int, default=64)
    ap.add_argument("--num-clusters", type=int, default=8)
    ap.add_argument("--num-landmarks", default=None,
                    help="Nyström landmark count: an int, or 'auto' to "
                         "autotune from the eigengap/drift history")
    ap.add_argument("--landmarks", default="uniform",
                    choices=["uniform", "leverage", "kmeans++"])
    ap.add_argument("--policy", default="stratified",
                    choices=["stratified", "dqn"],
                    help="cohort draw: uniform stratified, or the "
                         "paper's cluster-level DQN (Algorithm II)")
    ap.add_argument("--rounds", type=int, default=5)
    args = ap.parse_args()

    if args.cohort:
        _cohort_main(args)
        return

    from repro.configs import get_config

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    server = Server(cfg, args.batch, args.prompt_len + args.gen_len,
                    temperature=args.temperature, seed=args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32),
                    args.gen_len)
            for i in range(args.batch)]
    t0 = time.time()
    done = server.serve_batch(reqs)
    print(f"served {len(done)} requests in {time.time()-t0:.1f}s "
          f"({server.last_decode_tok_s:,.1f} decode tok/s)")
    for r in done[:2]:
        print(f"req {r.uid}: first 10 generated tokens {r.generated[:10]}")


if __name__ == "__main__":
    main()
