"""Serving launchers: the LM server loop and the cohort-selection service.

``Server`` implements a small production-shaped LM loop: a request
queue, one prefill step per admitted batch, then token-by-token decode
with greedy or temperature sampling.  Used by examples/serve_lm.py; the
decode step is exactly the one the dry-run lowers for decode_32k /
long_500k.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --batch 4 --prompt-len 32 --gen-len 32

``CohortServer`` is the federated control-plane counterpart: it owns the
live client-embedding table and a ``repro.cohort.CohortEngine``, and
answers cohort requests with a cluster-stratified draw.  Because the
engine warm-starts and fingerprint-caches between requests, steady-state
selection cost is dominated by the (N, m) cross-affinity — sharded over
the cohort mesh when more than one device is visible.

  PYTHONPATH=src python -m repro.launch.serve --cohort 100000 \
      --cohort-size 64 --landmarks kmeans++ --rounds 5
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    generated: Optional[List[int]] = None


class Server:
    """Batched static-shape server (prefill once, decode step-by-step)."""

    def __init__(self, cfg, batch: int, max_seq: int, *, seed: int = 0,
                 temperature: float = 0.0):
        import jax
        from repro.models import transformer as T

        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        self.temperature = temperature
        key = jax.random.PRNGKey(seed)
        self.params = T.init_lm(key, cfg)
        self._prefill = jax.jit(
            lambda p, b, c: T.lm_prefill(p, cfg, b, c))
        self._decode = jax.jit(
            lambda p, t, c, pos: T.lm_decode_step(p, cfg, t, c, pos))
        self._rng = np.random.default_rng(seed)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / self.temperature
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.array([self._rng.choice(len(row), p=row) for row in p],
                        np.int32)

    def serve_batch(self, requests: List[Request]) -> List[Request]:
        import jax.numpy as jnp
        from repro.models import transformer as T

        assert len(requests) <= self.batch
        while len(requests) < self.batch:                  # pad the batch
            requests = requests + [Request(-1, requests[0].prompt, 0)]
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.batch, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, : len(r.prompt)] = r.prompt

        caches = T.init_lm_cache(self.cfg, self.batch, self.max_seq)
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)},
                                       caches)
        out = [[] for _ in requests]
        tok = self._sample(np.asarray(logits))
        steps = max(r.max_new_tokens for r in requests)
        t0 = time.time()
        for s in range(steps):
            for i, r in enumerate(requests):
                if s < r.max_new_tokens:
                    out[i].append(int(tok[i]))
            logits, caches = self._decode(self.params,
                                          jnp.asarray(tok[:, None]),
                                          caches, jnp.int32(plen + s))
            tok = self._sample(np.asarray(logits))
        dt = time.time() - t0
        self.last_decode_tok_s = self.batch * steps / max(dt, 1e-9)
        for r, gen in zip(requests, out):
            r.generated = gen
        return [r for r in requests if r.uid >= 0]


class CohortServer:
    """Cohort-selection service backed by a :class:`CohortEngine`.

    Holds the latest (N, d) client-embedding table (updated as client
    deltas stream in via ``update_embeddings``) and serves
    ``select_cohort(size)`` requests: the engine clusters the table —
    dense, Nyström, or mesh-sharded Nyström depending on N and devices —
    and the cohort is drawn round-robin across clusters, de-biasing the
    draw toward minority clusters exactly as the paper's Algorithm II
    does for its DQN-chosen clusters.  Embedding updates only invalidate
    the engine's exact-match cache; small drift keeps the warm-start
    path, so steady-state request latency excludes landmark reselection
    and cold eigensolves.
    """

    def __init__(self, num_clients: int, embed_dim: int, *,
                 config=None, seed: int = 0):
        from repro.cohort import CohortConfig, CohortEngine

        self.embeds = np.zeros((num_clients, embed_dim), np.float32)
        self.engine = CohortEngine(config or CohortConfig(), seed=seed)
        self.rng = np.random.default_rng(seed)
        self.last_select_s = 0.0

    def update_embeddings(self, client_ids, new_embeds) -> None:
        """Overwrite the embedding rows of ``client_ids`` in place."""
        self.embeds[np.asarray(client_ids)] = np.asarray(
            new_embeds, np.float32)

    def select_cohort(self, cohort_size: int):
        """Returns ``(client_ids (cohort_size,), CohortResult)``."""
        t0 = time.perf_counter()
        res = self.engine.select(self.embeds)
        pools = [list(np.flatnonzero(res.assign == c))
                 for c in range(res.k)]
        for pool in pools:
            self.rng.shuffle(pool)
        picked: list = []
        while len(picked) < cohort_size and any(pools):
            for pool in pools:
                if pool and len(picked) < cohort_size:
                    picked.append(pool.pop())
        self.last_select_s = time.perf_counter() - t0
        return np.asarray(picked[:cohort_size]), res


def _cohort_main(args) -> None:
    """Cohort-service demo loop: N synthetic clients, drifting embeddings."""
    from repro.cohort import CohortConfig

    rng = np.random.default_rng(args.seed)
    d = 8
    centers = rng.normal(size=(args.num_clusters, d)).astype(np.float32) * 6
    assign_true = rng.integers(0, args.num_clusters, args.cohort)
    embeds = (centers[assign_true]
              + rng.normal(size=(args.cohort, d)).astype(np.float32))
    server = CohortServer(
        args.cohort, d, seed=args.seed,
        config=CohortConfig(num_clusters=args.num_clusters,
                            landmarks=args.landmarks,
                            num_landmarks=args.num_landmarks))
    server.update_embeddings(np.arange(args.cohort), embeds)
    for r in range(args.rounds):
        ids, res = server.select_cohort(args.cohort_size)
        # the selected cohort trains and drifts; everyone else is static
        server.update_embeddings(
            ids, server.embeds[ids]
            + 0.01 * rng.normal(size=(len(ids), d)).astype(np.float32))
        print(f"round {r}: {len(ids)} clients from {res.k} clusters "
              f"({res.method}/{res.source}) in {server.last_select_s:.3f}s "
              f"({args.cohort / max(server.last_select_s, 1e-9):,.0f} "
              f"clients/s)")
    print(f"engine stats: {server.engine.stats}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cohort", type=int, default=0, metavar="N",
                    help="serve cohort selection for N clients instead "
                         "of the LM loop")
    ap.add_argument("--cohort-size", type=int, default=64)
    ap.add_argument("--num-clusters", type=int, default=8)
    ap.add_argument("--num-landmarks", type=int, default=None)
    ap.add_argument("--landmarks", default="uniform",
                    choices=["uniform", "leverage", "kmeans++"])
    ap.add_argument("--rounds", type=int, default=5)
    args = ap.parse_args()

    if args.cohort:
        _cohort_main(args)
        return

    from repro.configs import get_config

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    server = Server(cfg, args.batch, args.prompt_len + args.gen_len,
                    temperature=args.temperature, seed=args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32),
                    args.gen_len)
            for i in range(args.batch)]
    t0 = time.time()
    done = server.serve_batch(reqs)
    print(f"served {len(done)} requests in {time.time()-t0:.1f}s "
          f"({server.last_decode_tok_s:,.1f} decode tok/s)")
    for r in done[:2]:
        print(f"req {r.uid}: first 10 generated tokens {r.generated[:10]}")


if __name__ == "__main__":
    main()
