"""Serving launchers: the LM decode engine and the cohort-selection service.

``Server`` is a continuous-batching LM server: a
:class:`DecodeScheduler` owns a **slot table** (one KV-cache slot per
batch lane, independently resettable) and a request queue.  Finished or
cache-full requests retire their slot *mid-decode* and the next queued
request is admitted into it — a slot-targeted prefill
(``lm_prefill_slot``) fills only that lane — so the decode jit keeps
running at full batch width with per-slot active masking.  Decode runs
with **per-request cache positions**: row i writes its token's KV at
its own ``pos[i]`` and attends only ``[0, pos[i]]``, which makes
heterogeneous prompt lengths *exact* — each request's continuation is
bit-identical to decoding it alone (pad and stale-slot KV can never
leak).  ``serve_batch`` survives as a thin wrapper (submit + drain);
the decode step is exactly the one the dry-run lowers for decode_32k.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
      --batch 4 --prompt-len 32 --gen-len 32 --requests 12 --mixed

``CohortServer`` is the federated control-plane counterpart: it owns the
live client-embedding table (versioned, copy-on-write, so embedding
updates never tear a concurrent selection) and a
``repro.cohort.CohortEngine``, and answers cohort requests either with a
cluster-stratified draw (``policy="stratified"``) or with the paper's
Algorithm II (``policy="dqn"``): a :class:`repro.policy.ClusterPolicy`
scores the clusters and draws the cohort ε-greedily, trained online from
the accuracy signal reported back via ``observe_round``.  Because the
engine warm-starts and fingerprint-caches between requests, steady-state
selection cost is dominated by the (N, m) cross-affinity — sharded over
the cohort mesh when more than one device is visible.  ``stats()``
exposes the whole serving picture: engine cache/warm/cold counters,
per-phase latencies, table version, and the policy's ε / replay fill.

  PYTHONPATH=src python -m repro.launch.serve --cohort 100000 \
      --cohort-size 64 --landmarks kmeans++ --policy dqn --rounds 5

Multi-tenant serving lives one layer up, in
``repro.launch.frontend.CohortFrontend``: named per-model-family shards
(each a ``CohortServer``) and a coalescing select path that batches
concurrent same-version requests behind one engine solve
(``CohortServer.select_cohorts``).  ``--tenants T`` switches the
``--cohort`` demo to that frontend:

  PYTHONPATH=src python -m repro.launch.serve --cohort 20000 \
      --tenants 4 --concurrency 16 --cohort-size 64 --rounds 5
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import threading
import time
from typing import Deque, List, Optional

import numpy as np

#: smoothing factor for the decode tokens/sec EMA in DecodeScheduler.stats().
_TOK_S_EMA = 0.2


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int
    generated: Optional[List[int]] = None


class DecodeScheduler:
    """Continuous-batching decode engine: slot table + request queue.

    One KV-cache **slot** per batch lane (``repro.models.transformer
    .init_lm_cache`` — leaves stacked ``(repeats, batch, ...)``, batch
    axis = slot table).  The loop per :meth:`step`:

    1. **admit** — every free slot pops the queue: the new request's
       prompt is prefilled *into that slot only*
       (``lm_prefill_slot`` zeroes the lane and fills it; other slots
       keep decoding state untouched), its first token is sampled from
       its own last-prompt-position logits, and the slot's cache
       position starts at the true (unpadded) prompt length.
    2. **decode** — ONE jitted ``lm_decode_step`` over the full batch
       with per-request positions: row i writes at ``pos[i]`` and
       attends ``[0, pos[i]]``, so pad/stale-slot KV cannot leak and
       mixed-length continuations are exact.  Empty slots ride along
       masked-inactive (their logits are discarded and they generate
       nothing — no wasted "filler" steps are ever accounted).
    3. **retire** — requests that produced ``max_new_tokens`` tokens
       (or filled the cache: ``truncated``) free their slot mid-decode
       for the next admit.

    Sampling is vectorized: greedy argmax, or Gumbel-max for
    temperature sampling (``argmax(logits/T + Gumbel)`` is one exact
    softmax draw per row — no per-row Python ``rng.choice`` loop).
    Everything is deterministic under a fixed seed.

    Prompts are right-padded to a multiple of ``prefill_bucket`` to
    bound jit retraces (one per distinct padded length).  Bucketing
    never changes results: the first token is sampled at the true last
    prompt position (causal attention — pad cannot leak backwards) and
    every padded KV entry is overwritten by the real decode write at
    that position before the mask ever exposes it.

    Thread-safe: ``submit`` may race ``step``/``drain`` from another
    thread.  ``_sched_lock`` (slot table + queue) and ``_stats_lock``
    (counters, innermost) are ranked in
    ``repro.analysis.watchdog.SERVING_LOCK_ORDER``.
    """

    def __init__(self, cfg, params, batch: int, max_seq: int, *,
                 seed: int = 0, temperature: float = 0.0,
                 prefill_bucket: int = 8):
        import jax
        from repro.models import transformer as T

        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.prefill_bucket = max(1, int(prefill_bucket))
        self._rng = np.random.default_rng(seed)
        self._prefill_slot = jax.jit(
            lambda p, t, c, slot, last: T.lm_prefill_slot(
                p, cfg, {"tokens": t}, c, slot, last_pos=last))
        self._decode = jax.jit(
            lambda p, t, c, pos: T.lm_decode_step(p, cfg, t, c, pos))

        # slot table + queue (one writer at a time under _sched_lock;
        # _stats_lock is the innermost leaf for dashboard counters)
        self._sched_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self.caches = T.init_lm_cache(cfg, batch, max_seq)  # guarded-by: _sched_lock
        self._reqs: List[Optional[Request]] = [None] * batch  # guarded-by: _sched_lock
        self._pos = np.zeros(batch, np.int32)       # guarded-by: _sched_lock
        self._tok = np.zeros(batch, np.int32)       # guarded-by: _sched_lock
        self._need = np.zeros(batch, np.int64)      # guarded-by: _sched_lock
        self._queue: Deque[Request] = collections.deque()  # guarded-by: _sched_lock
        self._completed: List[Request] = []         # guarded-by: _sched_lock
        self._counters = {  # guarded-by: _stats_lock
            "admitted": 0, "retired": 0, "truncated": 0, "prefills": 0,
            "decode_steps": 0, "decode_tokens": 0, "tokens_generated": 0}
        self._decode_seconds = 0.0                  # guarded-by: _stats_lock
        self._tok_s_ema = 0.0                       # guarded-by: _stats_lock

    # -- sampling ---------------------------------------------------------
    def _sample(self, logits: np.ndarray) -> np.ndarray:
        """Greedy argmax, or one vectorized Gumbel-max softmax draw per
        row (identical in distribution to ``rng.choice(p=softmax)``)."""
        if self.temperature <= 0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / self.temperature
        g = self._rng.gumbel(size=z.shape)
        return np.argmax(z + g, axis=-1).astype(np.int32)

    # -- request intake ---------------------------------------------------
    def submit(self, request: Request) -> None:
        """Enqueue one request; it is admitted when a slot frees up."""
        plen = len(request.prompt)
        if plen < 1:
            raise ValueError(f"request {request.uid}: empty prompt")
        if plen > self.max_seq:
            raise ValueError(
                f"request {request.uid}: prompt length {plen} exceeds "
                f"max_seq {self.max_seq}")
        with self._sched_lock:
            self._queue.append(request)

    # -- scheduler core ---------------------------------------------------
    def step(self) -> bool:
        """One scheduler tick: admit, decode once, retire.

        Admission pops the queue into every free slot (a request with
        ``max_new_tokens <= 0`` completes immediately without touching a
        slot — no filler decode steps, no skewed timing); decode runs
        ONE jitted step over the full batch with inactive slots masked;
        finished or cache-full requests retire their slot mid-decode.
        Returns False only when the engine is fully idle (no queued
        requests, no active slots) — the drain-loop termination signal.
        """
        import jax.numpy as jnp

        with self._sched_lock:
            # -- admit -------------------------------------------------
            worked = False
            for i in range(self.batch):
                if self._reqs[i] is not None:
                    continue
                if not self._queue:
                    break
                req = self._queue.popleft()
                worked = True
                req.generated = []
                if req.max_new_tokens <= 0:
                    self._completed.append(req)
                    with self._stats_lock:
                        self._counters["retired"] += 1
                    continue
                plen = len(req.prompt)
                bucket = self.prefill_bucket
                padded = min(self.max_seq, -(-plen // bucket) * bucket)
                toks = np.zeros((1, padded), np.int32)
                toks[0, :plen] = req.prompt
                logits, self.caches = self._prefill_slot(
                    self.params, jnp.asarray(toks), self.caches,
                    jnp.int32(i), jnp.asarray([plen - 1], np.int32))
                first = int(self._sample(np.asarray(logits))[0])
                req.generated.append(first)
                # done at admit: single-token request, or no cache room
                # left to write the first token's KV for further decode
                done_now = req.max_new_tokens == 1 or plen >= self.max_seq
                with self._stats_lock:
                    self._counters["admitted"] += 1
                    self._counters["prefills"] += 1
                    self._counters["tokens_generated"] += 1
                    if done_now:
                        self._counters["retired"] += 1
                        if req.max_new_tokens > 1:
                            self._counters["truncated"] += 1
                if done_now:
                    self._completed.append(req)
                    continue
                self._reqs[i] = req
                self._pos[i] = plen
                self._tok[i] = first
                self._need[i] = req.max_new_tokens - 1

            # -- decode ------------------------------------------------
            active = np.flatnonzero(self._need > 0)
            if active.size == 0:
                return worked
            t0 = time.perf_counter()
            logits, self.caches = self._decode(
                self.params, jnp.asarray(self._tok[:, None]), self.caches,
                jnp.asarray(self._pos))
            nxt = self._sample(np.asarray(logits))
            dt = time.perf_counter() - t0

            # -- retire ------------------------------------------------
            retired = truncated = 0
            for i in active:
                req = self._reqs[i]
                req.generated.append(int(nxt[i]))
                self._tok[i] = nxt[i]
                self._pos[i] += 1
                self._need[i] -= 1
                if self._need[i] <= 0:
                    self._reqs[i] = None
                    self._need[i] = 0
                    self._completed.append(req)
                    retired += 1
                elif self._pos[i] >= self.max_seq:
                    # cache full: retire mid-decode with what we have
                    self._reqs[i] = None
                    self._need[i] = 0
                    self._completed.append(req)
                    retired += 1
                    truncated += 1
            with self._stats_lock:
                # count only REAL generated tokens — inactive/filler
                # slots produce nothing (the old lockstep loop divided
                # batch*steps by wall time and over-counted)
                self._counters["retired"] += retired
                self._counters["truncated"] += truncated
                self._counters["decode_steps"] += 1
                self._counters["decode_tokens"] += int(active.size)
                self._counters["tokens_generated"] += int(active.size)
                self._decode_seconds += dt
                rate = active.size / max(dt, 1e-9)
                self._tok_s_ema = (
                    rate if self._counters["decode_steps"] == 1
                    else self._tok_s_ema
                    + _TOK_S_EMA * (rate - self._tok_s_ema))
        return True

    def completed(self) -> List[Request]:
        """Harvest requests finished so far without driving the engine
        (streaming callers interleave this with :meth:`step`)."""
        with self._sched_lock:
            done, self._completed = self._completed, []
        return done

    def drain(self) -> List[Request]:
        """Run the scheduler until idle; return newly completed requests."""
        while self.step():
            pass
        return self.completed()

    # -- observability ----------------------------------------------------
    def stats(self) -> dict:
        """Serving dashboard: slot occupancy, queue depth, counters.

        ``admitted`` / ``retired`` / ``truncated`` count requests
        (truncated = retired early because the slot's cache filled);
        ``decode_tokens`` counts only tokens actually generated by
        decode steps (inactive slots contribute nothing);
        ``tokens_generated`` additionally includes each request's first
        token, sampled at prefill; ``tok_s_ema`` smooths the per-step
        decode rate with factor ``_TOK_S_EMA``.
        """
        with self._sched_lock:
            occupied = sum(r is not None for r in self._reqs)
            queue_depth = len(self._queue)
            with self._stats_lock:
                counters = dict(self._counters)
                decode_seconds = self._decode_seconds
                tok_s_ema = self._tok_s_ema
        return {
            **counters,
            "slots": self.batch,
            "occupied": occupied,
            "queue_depth": queue_depth,
            "decode_seconds": decode_seconds,
            "tok_s_ema": tok_s_ema,
        }


class Server:
    """Continuous-batching LM server over a :class:`DecodeScheduler`.

    ``serve_batch`` is the compatibility wrapper around the scheduler:
    submit every request, drain, return them (mutated in place, original
    order).  For streaming workloads use :meth:`submit` /
    :meth:`DecodeScheduler.step` / :meth:`drain` directly.
    """

    def __init__(self, cfg, batch: int, max_seq: int, *, seed: int = 0,
                 temperature: float = 0.0, prefill_bucket: int = 8):
        import jax
        from repro.models import transformer as T

        self.cfg = cfg
        self.batch = batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.params = T.init_lm(jax.random.PRNGKey(seed), cfg)
        self.scheduler = DecodeScheduler(
            cfg, self.params, batch, max_seq, seed=seed,
            temperature=temperature, prefill_bucket=prefill_bucket)
        self.last_decode_tok_s = 0.0

    def submit(self, request: Request) -> None:
        self.scheduler.submit(request)

    def drain(self) -> List[Request]:
        return self.scheduler.drain()

    def stats(self) -> dict:
        """Scheduler stats plus the last ``serve_batch`` decode rate."""
        return {**self.scheduler.stats(),
                "last_decode_tok_s": self.last_decode_tok_s}

    def serve_batch(self, requests: List[Request]) -> List[Request]:
        """Serve ``requests`` to completion (any count — the queue admits
        them as slots free up) and return them in the original order.

        ``last_decode_tok_s`` counts only real generated tokens over
        the decode wall time of this call — short or absent requests no
        longer inflate the rate, and partial batches run no filler
        decode steps at all.
        """
        if not requests:
            return []
        before = self.scheduler.stats()
        for req in requests:
            self.scheduler.submit(req)
        self.scheduler.drain()
        after = self.scheduler.stats()
        toks = after["decode_tokens"] - before["decode_tokens"]
        secs = after["decode_seconds"] - before["decode_seconds"]
        self.last_decode_tok_s = toks / max(secs, 1e-9)
        return list(requests)


#: smoothing factor for the server's per-phase latency EMAs.
_LATENCY_EMA = 0.2
#: smoothing factor for the per-cluster reward EMAs in the policy state
#: (independent knob from the latency smoothing; they just share a value).
_REWARD_EMA = 0.2


class CohortServer:
    """Cohort-selection service backed by a :class:`CohortEngine`.

    Holds the latest (N, d) client-embedding table (updated as client
    deltas stream in via ``update_embeddings``) and serves
    ``select_cohort(size)`` requests: the engine clusters the table —
    dense, Nyström, or mesh-sharded Nyström depending on N and devices —
    and the cohort is drawn from the clusters by the configured policy:

    * ``policy="stratified"`` — round-robin across clusters, the
      uniform de-biasing draw.
    * ``policy="dqn"`` — the paper's Algorithm II: a
      :class:`repro.policy.ClusterPolicy` (cluster-level Deep-Q agent)
      chooses the cluster for every cohort slot ε-greedily; callers
      report each round's resulting accuracy via :meth:`observe_round`,
      which shapes the reward (FAVOR's ``Ξ^(acc − target) − 1``),
      updates the replay buffer, and takes one TD training step — the
      policy learns online which clusters to favor while serving.

    Concurrency: the embedding table is **versioned copy-on-write with a
    coalesced delta buffer** — ``update_embeddings`` appends the changed
    rows (O(delta), no full-table copy) and bumps the version;
    ``snapshot`` materializes a fresh immutable table only when deltas
    are actually pending, so a million-client table is not re-shipped
    per round and a selection in flight always clusters one internally
    consistent table.  Selections are serialized on ``_select_lock``;
    engine entries (inline or background) are serialized on
    ``_solve_lock`` because the engine's warm-start state is
    single-writer.  Embedding updates only invalidate the engine's
    exact-match cache; small drift keeps the warm-start path, so
    steady-state request latency excludes landmark reselection and cold
    eigensolves.

    Streaming (``streaming=StreamingSpec(...)``): re-clustering moves
    off the select path entirely — every ``update_embeddings`` marks the
    table dirty on a :class:`repro.streaming.BackgroundSolver`, whose
    worker snapshots the freshest table, runs ``engine.prepare`` +
    ``publish`` under ``_solve_lock``, and parks the finished
    ``(version, table, result)`` in the ``_published`` mailbox.  The
    next select swaps the mailbox into ``_served`` and draws from it —
    no solve inline — unless the served version has fallen more than
    ``max_stale_versions`` behind the table, which forces one inline
    solve (bounded staleness).  See docs/ARCHITECTURE.md ("Streaming
    re-clustering").

    Args:
        num_clients:  N, rows of the embedding table.
        embed_dim:    d, embedding width.
        config:       :class:`repro.cohort.CohortConfig` for the engine.
        seed:         seeds the engine, the draw rng, and the Q-network.
        policy:       "stratified" | "dqn".
        target_accuracy: reward pivot for the DQN policy's shaping.
        dqn_overrides: DQNConfig field overrides for ``policy="dqn"``.
        state_features: DQN serving-state layout — ``"rich"`` (default,
            ``5k + 1``: + per-cluster embedding dispersion and
            staleness), ``"system"`` (``7k + 1``: + per-cluster
            availability and mean-latency EMAs fed by
            ``observe_round(outcome=...)`` from the client-realism
            layer, so the policy can learn to avoid slow/flaky
            clusters), or ``"basic"`` (the legacy ``3k + 1``
            participation-only state; keeps replay buffers recorded
            against the narrow shape loadable).
        streaming:    :class:`repro.streaming.StreamingSpec` enabling
            double-buffered background re-clustering (+ admission knobs
            for the singular ``select_cohort`` path); None = solve
            inline as before.
        solver:       share a :class:`repro.streaming.BackgroundSolver`
            across servers (the frontend does); None with ``streaming``
            set creates (and owns) a private one.
        deduper:      share a :class:`repro.streaming.SolveDeduper` so
            identical-fingerprint tenants ride one solve; None disables
            dedupe for this server.
    """

    POLICIES = ("stratified", "dqn")

    def __init__(self, num_clients: int, embed_dim: int, *,
                 config=None, seed: int = 0, policy: str = "stratified",
                 target_accuracy: float = 0.85,
                 dqn_overrides: Optional[dict] = None,
                 state_features: str = "rich",
                 streaming=None, solver=None, deduper=None):
        from repro.cohort import CohortConfig, CohortEngine
        from repro.fed.metrics import serving_state_dim

        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"expected one of {self.POLICIES}")
        self.config = config or CohortConfig()
        self.engine = CohortEngine(self.config, seed=seed)
        self.rng = np.random.default_rng(seed)
        self.policy_name = policy
        self.target_accuracy = target_accuracy
        self.state_features = state_features
        k = self.config.num_clusters
        state_dim = serving_state_dim(k, state_features)  # validates knob
        if policy == "dqn":
            from repro.policy import ClusterPolicy
            # serving state = cluster_policy_state(): per-cluster
            # population / participation / reward EMA (+ dispersion and
            # staleness when "rich") + the last reported global accuracy
            self.policy = ClusterPolicy(k, state_dim=state_dim, seed=seed,
                                        dqn_overrides=dqn_overrides,
                                        state_features=state_features)
        else:
            self.policy = None

        table = np.zeros((num_clients, embed_dim), np.float32)
        table.setflags(write=False)       # snapshots must stay immutable
        self._write_lock = threading.Lock()
        self._select_lock = threading.Lock()
        # serializes engine entries: the inline select path and the
        # background solver's prepare/publish both mutate the engine's
        # warm-start state (ranked between _select_lock and _write_lock
        # in SERVING_LOCK_ORDER)
        self._solve_lock = threading.Lock()
        # mailbox the background solver fills and the select path drains
        self._publish_lock = threading.Lock()
        # leaf lock for dashboard state (innermost — see
        # repro.analysis.watchdog.SERVING_LOCK_ORDER): counters and
        # latency EMAs are mutated from BOTH the update path
        # (_write_lock held) and the select path (_select_lock held)
        # and read by stats(), so they need a lock of their own rather
        # than whichever path's lock happened to be held.
        self._stats_lock = threading.Lock()
        # versioned copy-on-write base + coalesced pending deltas:
        # update_embeddings appends O(delta) rows here and snapshot()
        # materializes base+deltas into a fresh immutable table lazily
        self._version = 0                 # guarded-by: _write_lock
        self._base = table                # guarded-by: _write_lock
        self._delta_ids: List[np.ndarray] = []    # guarded-by: _write_lock
        self._delta_rows: List[np.ndarray] = []   # guarded-by: _write_lock
        self._delta_pending = 0           # guarded-by: _write_lock
        self._materializations = 0        # guarded-by: _write_lock

        # streaming double-buffer: _published is the background solver's
        # finished (version, table, result); _served is the pair selects
        # currently draw from
        self._streaming = streaming
        self._published = None            # guarded-by: _publish_lock
        self._served = None               # guarded-by: _select_lock
        self._closed = False              # guarded-by: _select_lock
        self._deduper = deduper
        self._own_solver = streaming is not None and solver is None
        if self._own_solver:
            from repro.streaming import BackgroundSolver
            solver = BackgroundSolver(streaming.solver_workers)
        self._solver = solver if streaming is not None else None
        self.admission = None
        if streaming is not None and (streaming.max_queue_depth is not None
                                      or streaming.rate_per_s is not None):
            from repro.streaming import AdmissionController
            self.admission = AdmissionController(
                max_queue_depth=streaming.max_queue_depth,
                rate_per_s=streaming.rate_per_s, burst=streaming.burst)

        self._participation = np.zeros(k, np.float64)   # guarded-by: _select_lock
        self._reward_ema = np.zeros(k, np.float32)      # guarded-by: _select_lock
        # selects since each cluster last contributed a served client
        # (the "rich" state's staleness feature)
        self._staleness = np.zeros(k, np.float64)       # guarded-by: _select_lock
        # client-realism EMAs behind the "system" state: per-cluster
        # completion rate and mean simulated latency, fed by
        # observe_round(outcome=...); availability starts optimistic (1)
        self._avail_ema = np.ones(k, np.float64)        # guarded-by: _select_lock
        self._latency_ema_s = np.zeros(k, np.float64)   # guarded-by: _select_lock
        # cluster assignment of the latest served solve (any policy) —
        # maps an observe_round outcome's client ids back to clusters
        self._last_assign = None                        # guarded-by: _select_lock
        self.prev_accuracy = 0.0                        # guarded-by: _select_lock
        # parked (state_vec, actions, assign, table) until observe_round
        self._pending = None                            # guarded-by: _select_lock
        self._latency = {  # guarded-by: _stats_lock
            "solve_s": 0.0, "draw_s": 0.0, "total_s": 0.0}
        # running means per RoundResult.timings phase
        self._round_timings: dict = {}                  # guarded-by: _stats_lock
        self._counters = {  # guarded-by: _stats_lock
            "requests": 0, "batches": 0, "updates": 0,
            "rounds_observed": 0, "dropped_transitions": 0,
            # streaming: background warms landed / selects answered from
            # a warmed result / selects that had to solve inline / warms
            # adopted from another tenant's identical-fingerprint solve
            "warm_ahead": 0, "served_warm": 0, "forced_inline": 0,
            "dedupe_hit": 0}
        self.last_select_s = 0.0                        # guarded-by: _select_lock

    # -- embedding table (versioned copy-on-write + delta buffer) --------
    @property
    def embeds(self) -> np.ndarray:
        """Current (read-only) embedding-table snapshot."""
        return self.snapshot()[1]

    @property
    def version(self) -> int:
        """Table version; bumps on every ``update_embeddings``."""
        return self._version

    def snapshot(self):
        """Read a consistent ``(version, table)``; the table is immutable.

        Materializes pending deltas into a fresh copy-on-write table
        only when there are any — repeated snapshots between updates
        return the same frozen array, and readers holding an older
        snapshot are never affected.
        """
        return self._flush()

    def _flush(self):
        """Apply pending deltas to the base table (self-locking)."""
        with self._write_lock:
            if self._delta_pending:
                table = self._base.copy()
                for ids, rows in zip(self._delta_ids, self._delta_rows):
                    table[ids] = rows
                table.setflags(write=False)
                self._base = table
                self._delta_ids = []
                self._delta_rows = []
                self._delta_pending = 0
                self._materializations += 1
            return self._version, self._base

    def update_embeddings(self, client_ids, new_embeds) -> None:
        """Replace the embedding rows of ``client_ids``.

        O(delta): the rows are appended to a pending-delta buffer and
        the version bumps; the O(N·d) materialization happens at the
        next :meth:`snapshot` (deltas applied in arrival order, so
        later writes to the same client win).  Readers holding a
        previous snapshot are unaffected.  When ``streaming`` is
        enabled the update also marks this server dirty on the
        background solver, so a fresh solve starts warming immediately.
        """
        ids = np.array(client_ids, dtype=np.int64)   # copy: deferred apply
        rows = np.array(new_embeds, dtype=np.float32)
        n, d = self._base.shape
        if rows.ndim != 2 or rows.shape != (len(ids), d):
            raise ValueError(f"rows shape {rows.shape} != ({len(ids)}, {d})")
        if len(ids) and (ids.min() < -n or ids.max() >= n):
            raise IndexError(f"client_ids out of range for {n} clients")
        flush_now = False
        with self._write_lock:
            self._delta_ids.append(ids)
            self._delta_rows.append(rows)
            self._delta_pending += len(ids)
            self._version += 1
            # bound the buffer: once pending rows rival the table size a
            # materialization is no longer a saving, only deferred work
            flush_now = self._delta_pending >= n
        if flush_now:
            self._flush()
        with self._stats_lock:
            self._counters["updates"] += 1
        if self._solver is not None:
            self._solver.submit(id(self), self._background_warm)

    # -- streaming (background warm + shutdown) ---------------------------
    def _background_warm(self) -> None:
        """Solve-ahead task run on a :class:`BackgroundSolver` worker.

        Snapshots the freshest table, computes (or, with dedupe, adopts)
        a :class:`repro.cohort.PreparedSolve` for it, publishes it into
        the engine under ``_solve_lock``, and parks the finished
        ``(version, table, result)`` in the ``_published`` mailbox for
        the next select to swap in.  Never takes ``_select_lock`` — the
        serving path is never blocked behind a background solve.
        """
        version, table = self.snapshot()
        with self._publish_lock:
            pub = self._published
        if pub is not None and pub[0] >= version:
            return                      # already warmed this generation
        ticket = prep = None
        if self._deduper is not None:
            from repro.cohort import CohortEngine
            # key on (table content, engine config): identical tables
            # under different cluster counts / methods must NOT share a
            # solve — the adopted result's k would be wrong
            ticket, prep = self._deduper.begin(
                (CohortEngine.fingerprint(table), repr(self.config)))
        if prep is not None:            # adopt another tenant's solve
            with self._solve_lock:
                res = self.engine.publish(prep, count=False)
            with self._stats_lock:
                self._counters["dedupe_hit"] += 1
        else:
            try:
                with self._solve_lock:
                    own = self.engine.prepare(table)
                    res = (None if own is None
                           else self.engine.publish(own))
            except BaseException:
                if ticket is not None:
                    self._deduper.abort(ticket)
                raise
            if ticket is not None:
                if own is not None:
                    self._deduper.complete(ticket, own)
                else:
                    self._deduper.abort(ticket)
            if res is None:
                return                  # engine already current: no-op
        with self._publish_lock:
            if self._published is None or version > self._published[0]:
                self._published = (version, table, res)
        with self._stats_lock:
            self._counters["warm_ahead"] += 1

    def close(self, timeout: Optional[float] = None) -> None:
        """Stop serving: reject new selects, stop an owned solver.

        New ``select_cohort(s)`` calls raise
        :class:`repro.streaming.ServiceClosedError`; a background solver
        created by this server (not a shared one) is drained and joined.
        Idempotent.
        """
        with self._select_lock:
            self._closed = True
        if self._own_solver and self._solver is not None:
            self._solver.close(timeout)

    # -- serving ----------------------------------------------------------
    def _ema(self, name: str, value: float) -> None:
        """Fold one latency sample into the EMA (takes the stats lock)."""
        with self._stats_lock:
            prev = self._latency[name]
            self._latency[name] = (
                value if self._counters["requests"] == 0
                else prev + _LATENCY_EMA * (value - prev))

    def _policy_state(self, assign: np.ndarray,
                      table: np.ndarray) -> np.ndarray:
        from repro.fed.metrics import cluster_policy_state
        rich = self.state_features in ("rich", "system")
        system = self.state_features == "system"
        return cluster_policy_state(
            assign, self.config.num_clusters,
            self._participation, self._reward_ema, self.prev_accuracy,
            embeds=table if rich else None,
            staleness=self._staleness if rich else None,
            availability=self._avail_ema if system else None,
            latency_s=self._latency_ema_s if system else None,
            features=self.state_features)

    def select_cohort(self, cohort_size: int):
        """Serve one cohort; returns ``(client_ids, CohortResult)``.

        ``client_ids`` has ``cohort_size`` entries unless the table has
        fewer clients.  With ``policy="dqn"`` the draw's (state,
        actions) pair is parked until :meth:`observe_round` reports the
        round's accuracy.  When the streaming spec sets admission knobs
        this path sheds with a typed
        :class:`repro.streaming.ShedError` before touching the engine.
        """
        if self.admission is not None:
            self.admission.try_admit()
            try:
                return self.select_cohorts([cohort_size])[0]
            finally:
                self.admission.release()
        return self.select_cohorts([cohort_size])[0]

    def select_cohorts(self, cohort_sizes: Optional[List[int]] = None, *,
                       sizes_fn=None):
        """Serve a batch of cohort requests from ONE engine solve.

        This is the coalesced entry point the
        :class:`repro.launch.frontend.CohortFrontend` batches concurrent
        ``select_cohort`` calls into: the embedding table is snapshotted
        once, the engine runs once (``select_batched``), and every
        request draws from the **same shared cluster pools** — pools are
        popped without replacement across the whole batch, so no client
        is served to two cohorts of the same batch.  Returns one
        ``(client_ids, CohortResult)`` pair per requested size; the
        ``CohortResult`` is the single solve shared by the batch.

        ``sizes_fn`` (exclusive with ``cohort_sizes``) defers the batch
        membership decision until the select lock is actually held: the
        frontend passes a callback that seals its in-flight batch at
        that moment, so requests arriving while an earlier solve holds
        the lock still coalesce into this one — natural batching with
        zero added latency for uncontended callers.

        With ``policy="dqn"`` the batch parks ONE combined transition
        (the shared pre-draw state with every slot's cluster action
        across the batch); the next :meth:`observe_round` credits them
        all — the batch is one logical round of the serve contract.
        """
        if (cohort_sizes is None) == (sizes_fn is None):
            raise ValueError(
                "select_cohorts takes exactly one of cohort_sizes or "
                "sizes_fn")
        if cohort_sizes is not None and not len(cohort_sizes):
            return []
        with self._select_lock:
            if self._closed:
                from repro.streaming import ServiceClosedError
                raise ServiceClosedError("CohortServer is closed")
            sizes = [int(s) for s in (cohort_sizes if sizes_fn is None
                                      else sizes_fn())]
            if not sizes:
                return []
            t0 = time.perf_counter()
            version, table = self.snapshot()
            res = None
            if self._streaming is not None:
                # drain the background solver's mailbox: swap in the
                # warmed (version, table, result) if it is newer than
                # what we're serving
                with self._publish_lock:
                    pub = self._published
                if pub is not None and (self._served is None
                                        or pub[0] > self._served[0]):
                    self._served = pub
                if self._served is not None:
                    max_stale = self._streaming.max_stale_versions
                    if (max_stale is None
                            or version - self._served[0] <= max_stale):
                        _, table, res = self._served
                        with self._stats_lock:
                            self._counters["served_warm"] += 1
            if res is None:
                # non-streaming, or nothing warmed yet / served version
                # too stale: solve inline
                with self._solve_lock:
                    res = self.engine.select_batched(
                        table, requests=len(sizes))
                if self._streaming is not None:
                    self._served = (version, table, res)
                    with self._stats_lock:
                        self._counters["forced_inline"] += 1
            t_solve = time.perf_counter()
            k = self.config.num_clusters
            self._last_assign = res.assign
            pools = {c: list(np.flatnonzero(res.assign == c))
                     for c in range(k)}
            cohorts: List[np.ndarray] = []
            if self.policy is not None:
                state = self._policy_state(res.assign, table)
                all_actions: List[int] = []
                for size in sizes:
                    picked, actions = self.policy.draw(
                        self.rng, state, pools, size)
                    cohorts.append(np.asarray(picked[:size], np.int64))
                    all_actions.extend(actions[: len(picked)])
                if self._pending is not None:
                    # the serve contract is select -> observe_round ->
                    # select; a second select (or batch) before the
                    # round report replaces the parked transition, and
                    # the earlier draw is never learned from — count it
                    # so the dashboard can see mis-sequenced callers
                    with self._stats_lock:
                        self._counters["dropped_transitions"] += 1
                self._pending = (state, all_actions, res.assign, table)
            else:
                for pool in pools.values():
                    self.rng.shuffle(pool)
                for size in sizes:
                    ordered = [pools[c] for c in range(res.k)]
                    picked: List[int] = []
                    while len(picked) < size and any(ordered):
                        for pool in ordered:
                            if pool and len(picked) < size:
                                picked.append(pool.pop())
                    cohorts.append(np.asarray(picked[:size], np.int64))
            flat = (np.concatenate(cohorts) if cohorts
                    else np.empty(0, np.int64))
            if len(flat):
                np.add.at(self._participation, res.assign[flat], 1.0)
            # staleness: every cluster ages one select; those that just
            # contributed a client reset to fresh
            self._staleness += 1.0
            if len(flat):
                self._staleness[np.unique(res.assign[flat])] = 0.0
            t1 = time.perf_counter()
            self._ema("solve_s", t_solve - t0)
            self._ema("draw_s", t1 - t_solve)
            self._ema("total_s", t1 - t0)
            with self._stats_lock:
                self._counters["requests"] += len(sizes)
                self._counters["batches"] += 1
            self.last_select_s = t1 - t0
            return [(picked, res) for picked in cohorts]

    def _outcome_cluster_rates(self, outcome):
        """Per-cluster completion/latency rates from a realism outcome.

        Maps ``outcome.selected`` through the last solve's assignment
        and bins the completed/dropped split and simulated round-trips
        per cluster.  Returns ``(seen, avail, latency)`` — a boolean
        mask of clusters observed this round plus this round's
        completion-rate and mean-latency vectors (the "system" state
        features) — or ``None`` when nothing maps.  Pure; the caller
        holds ``_select_lock`` (reads ``_last_assign``) and applies the
        EMA updates itself.
        """
        assign = self._last_assign
        if assign is None or not len(outcome.selected):
            return None
        k = self.config.num_clusters
        sel = np.asarray(outcome.selected)
        lat = np.asarray(outcome.latencies_s)
        in_table = (sel >= 0) & (sel < len(assign))
        sel, lat = sel[in_table], lat[in_table]
        if not len(sel):
            return None
        clusters = assign[sel]
        completed = np.isin(sel, np.asarray(outcome.completed))
        counts = np.bincount(clusters, minlength=k)[:k].astype(np.float64)
        hits = np.bincount(clusters, weights=completed.astype(np.float64),
                           minlength=k)[:k]
        lat_sum = np.bincount(clusters, weights=lat, minlength=k)[:k]
        seen = counts > 0
        avail = np.zeros(k)
        latency = np.zeros(k)
        avail[seen] = hits[seen] / counts[seen]
        latency[seen] = lat_sum[seen] / counts[seen]
        return seen, avail, latency

    def observe_round(self, accuracy: float, timings: Optional[dict] = None,
                      outcome=None) -> float:
        """Report a completed round back to the server; returns the reward.

        ``accuracy`` is the post-aggregation global-model accuracy of
        the round trained on the last served cohort; the reward is the
        paper's shaping ``Ξ^(acc − target) − 1``.  With ``policy="dqn"``
        this is the online learning step: the parked (state, actions)
        from :meth:`select_cohort` plus the new state go into the replay
        buffer and one TD minibatch runs.  ``timings`` (e.g.
        ``RoundResult.timings`` from ``repro.fed.rounds``) is folded
        into the per-phase running means reported by :meth:`stats`.
        ``outcome`` (a ``repro.fed.realism.RoundOutcome``) feeds the
        per-cluster availability/latency EMAs behind
        ``state_features="system"`` and, when present, blends the
        reward with deadline attainment (``repro.fed.realism
        .blended_reward``) so slow/flaky clusters are penalized.
        """
        from repro.core.selection import favor_reward

        if outcome is not None:
            from repro.fed.realism import blended_reward
            reward = blended_reward(accuracy, self.target_accuracy,
                                    outcome.attainment)
        else:
            reward = favor_reward(accuracy, self.target_accuracy)
        # same lock as select_cohort: a racing selection must not park a
        # new (state, actions) transition between our read of _pending
        # and its clear, or that round's learning step would be dropped
        with self._select_lock:
            if outcome is not None:
                rates = self._outcome_cluster_rates(outcome)
                if rates is not None:
                    seen, avail, latency = rates
                    self._avail_ema[seen] += _REWARD_EMA * (
                        avail[seen] - self._avail_ema[seen])
                    self._latency_ema_s[seen] += _REWARD_EMA * (
                        latency[seen] - self._latency_ema_s[seen])
            if self.policy is not None and self._pending is not None:
                state, actions, assign, table = self._pending
                for c in set(actions):
                    self._reward_ema[c] += _REWARD_EMA * (
                        reward - self._reward_ema[c])
                self.prev_accuracy = accuracy
                next_state = self._policy_state(assign, table)
                self.policy.observe(state, actions, reward, next_state)
                self.policy.train(self.rng)
                self._pending = None
            else:
                self.prev_accuracy = accuracy
            with self._stats_lock:
                if timings:
                    n = self._counters["rounds_observed"]
                    for phase, seconds in timings.items():
                        prev = self._round_timings.get(phase, 0.0)
                        self._round_timings[phase] = (
                            prev + (seconds - prev) / (n + 1))
                self._counters["rounds_observed"] += 1
        return reward

    def stats(self) -> dict:
        """One dict for the serving dashboard: engine, latency, policy.

        Keys: ``requests`` / ``batches`` (engine entries — ``requests /
        batches`` is the realized coalescing factor) / ``updates`` /
        ``rounds_observed`` / ``dropped_transitions`` counters (the last
        counts DQN draws replaced by a second ``select_cohort`` before
        their round was reported — mis-sequenced callers),
        ``table_version``, ``num_clients``, ``state_features``,
        ``engine`` (cache hits, warm/cold starts, solves, probes,
        batched-select counters, autotuned ``auto_m`` when enabled),
        ``latency_s`` (EMA solve/draw/total), ``round_timings_s``
        (running means of ingested ``RoundResult.timings`` phases),
        ``last_select`` (method/source/drift/k of the latest solve), and
        ``policy`` (kind plus ε / state dim / steps / replay fill for
        "dqn").

        Streaming adds the flat ``warm_ahead`` / ``served_warm`` /
        ``forced_inline`` / ``dedupe_hit`` counters (always present,
        zero when disabled), ``shed`` (selects rejected by admission
        control), and a ``streaming`` sub-dict: enabled flag,
        ``max_stale_versions``, the version currently served vs the
        table version, delta-buffer ``materializations``, and the
        admission/solver breakdowns.
        """
        last = self.engine.state.result
        policy = {"kind": self.policy_name}
        if self.policy is not None:
            policy.update(self.policy.stats())
        # one consistent snapshot of the dashboard state; the copies
        # also keep callers from mutating the live dicts
        with self._stats_lock:
            counters = dict(self._counters)
            latency = dict(self._latency)
            round_timings = dict(self._round_timings)
        admission = (None if self.admission is None
                     else self.admission.stats())
        shed = (0 if admission is None
                else admission["shed_queue"] + admission["shed_rate"])
        spec = self._streaming
        streaming = {
            "enabled": spec is not None,
            "max_stale_versions": (None if spec is None
                                   else spec.max_stale_versions),
            "served_version": (None if self._served is None
                               else self._served[0]),
            "materializations": self._materializations,
            "admission": admission,
        }
        if self._own_solver and self._solver is not None:
            streaming["solver"] = dict(self._solver.stats)
        return {
            **counters,
            "shed": shed,
            "table_version": self.version,
            "num_clients": self._base.shape[0],
            "state_features": self.state_features,
            "engine": dict(self.engine.stats),
            "streaming": streaming,
            "latency_s": latency,
            "round_timings_s": round_timings,
            "last_select": None if last is None else {
                "method": last.method, "source": last.source,
                "drift": last.drift, "k": last.k,
                "seconds": last.seconds},
            "policy": policy,
        }


def _cohort_main(args) -> None:
    """Cohort-service demo loop: N synthetic clients, drifting embeddings.

    With ``--policy dqn`` the loop also synthesizes a reward signal:
    clients of true cluster 0 are "stale" (contribute nothing), so round
    accuracy rises with the fraction of the cohort drawn outside it —
    over a few dozen rounds the policy's draw weights visibly shift away
    from the engine cluster covering that group.
    """
    from repro.cohort import CohortConfig
    from repro.streaming import StreamingSpec

    rng = np.random.default_rng(args.seed)
    d = 8
    centers = rng.normal(size=(args.num_clusters, d)).astype(np.float32) * 6
    assign_true = rng.integers(0, args.num_clusters, args.cohort)
    embeds = (centers[assign_true]
              + rng.normal(size=(args.cohort, d)).astype(np.float32))
    num_landmarks = args.num_landmarks
    if num_landmarks not in (None, "auto"):
        num_landmarks = int(num_landmarks)
    streaming = (StreamingSpec(max_stale_versions=args.max_stale)
                 if args.streaming else None)
    server = CohortServer(
        args.cohort, d, seed=args.seed, policy=args.policy,
        target_accuracy=0.85, streaming=streaming,
        config=CohortConfig(num_clusters=args.num_clusters,
                            landmarks=args.landmarks,
                            num_landmarks=num_landmarks))
    server.update_embeddings(np.arange(args.cohort), embeds)
    for r in range(args.rounds):
        ids, res = server.select_cohort(args.cohort_size)
        # synthetic round outcome: cohort quality = share of non-stale
        # clients (true cluster 0 is stale), reported back to the policy
        useful = float(np.mean(assign_true[ids] != 0)) if len(ids) else 0.0
        reward = server.observe_round(0.5 + 0.4 * useful)
        # the selected cohort trains and drifts; everyone else is static
        server.update_embeddings(
            ids, server.embeds[ids]
            + 0.01 * rng.normal(size=(len(ids), d)).astype(np.float32))
        print(f"round {r}: {len(ids)} clients from {res.k} clusters "
              f"({res.method}/{res.source}) in {server.last_select_s:.3f}s "
              f"({args.cohort / max(server.last_select_s, 1e-9):,.0f} "
              f"clients/s, reward {reward:+.3f})")
    server.close()
    import json
    print("server stats:", json.dumps(server.stats(), indent=2,
                                      default=float))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=0, metavar="R",
                    help="total LM requests to serve (default: one per "
                         "batch slot); R > batch exercises the "
                         "admit/retire scheduler")
    ap.add_argument("--mixed", action="store_true",
                    help="draw mixed prompt/generation lengths instead "
                         "of uniform --prompt-len/--gen-len")
    ap.add_argument("--cohort", type=int, default=0, metavar="N",
                    help="serve cohort selection for N clients instead "
                         "of the LM loop")
    ap.add_argument("--cohort-size", type=int, default=64)
    ap.add_argument("--num-clusters", type=int, default=8)
    ap.add_argument("--num-landmarks", default=None,
                    help="Nyström landmark count: an int, or 'auto' to "
                         "autotune from the eigengap/drift history")
    ap.add_argument("--landmarks", default="uniform",
                    choices=["uniform", "leverage", "kmeans++"])
    ap.add_argument("--policy", default="stratified",
                    choices=["stratified", "dqn"],
                    help="cohort draw: uniform stratified, or the "
                         "paper's cluster-level DQN (Algorithm II)")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--tenants", type=int, default=0, metavar="T",
                    help="with --cohort: serve T model-family tenants "
                         "through the coalescing CohortFrontend instead "
                         "of one CohortServer")
    ap.add_argument("--concurrency", type=int, default=16,
                    help="concurrent select workers in --tenants mode")
    ap.add_argument("--batch-window", type=float, default=0.0,
                    help="extra coalescing wait (s) in --tenants mode; "
                         "0 = natural batching only")
    ap.add_argument("--streaming", action="store_true",
                    help="double-buffered background re-clustering: "
                         "serve version v while a BackgroundSolver "
                         "warms v+1 (repro.streaming)")
    ap.add_argument("--max-stale", type=int, default=None, metavar="V",
                    help="with --streaming: force an inline solve when "
                         "the served version falls more than V table "
                         "versions behind (default: never)")
    args = ap.parse_args()

    if args.cohort:
        if args.tenants:
            from repro.launch.frontend import run_demo
            run_demo(args)
        else:
            _cohort_main(args)
        return

    from repro.configs import get_config

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    server = Server(cfg, args.batch, args.prompt_len + args.gen_len,
                    temperature=args.temperature, seed=args.seed)
    n_reqs = args.requests or args.batch
    reqs = []
    for i in range(n_reqs):
        if args.mixed:
            plen = int(rng.integers(1, args.prompt_len + 1))
            gen = int(rng.integers(1, args.gen_len + 1))
        else:
            plen, gen = args.prompt_len, args.gen_len
        reqs.append(Request(i, rng.integers(0, cfg.vocab_size,
                                            plen).astype(np.int32), gen))
    t0 = time.time()
    done = server.serve_batch(reqs)
    stats = server.stats()
    print(f"served {len(done)} requests in {time.time()-t0:.1f}s "
          f"({server.last_decode_tok_s:,.1f} decode tok/s)")
    print(f"scheduler: admitted={stats['admitted']} "
          f"retired={stats['retired']} truncated={stats['truncated']} "
          f"decode_steps={stats['decode_steps']} "
          f"decode_tokens={stats['decode_tokens']} "
          f"tok_s_ema={stats['tok_s_ema']:,.1f}")
    for r in done[:2]:
        print(f"req {r.uid}: first 10 generated tokens {r.generated[:10]}")


if __name__ == "__main__":
    main()
