"""Multi-tenant cohort-serving frontend: named tenants + request coalescing.

``CohortServer`` (``repro.launch.serve``) is a single-tenant service: one
embedding table, one engine, one policy, and a single-writer select path
— under concurrent traffic every ``select_cohort`` queues behind the
engine lock even when the callers would cluster the *same* table
version.  :class:`CohortFrontend` is the control-plane layer above it,
shaped like the shared selector service of the FL-systems literature
(FAVOR's device selector; the Kairouz et al. survey's cohort manager):

* **Tenants** — named ``(CohortEngine, ClusterPolicy)`` shards, one per
  model family, each a full :class:`~repro.launch.serve.CohortServer`
  with its own embedding table, :class:`~repro.cohort.CohortConfig`,
  seed, and policy.  Tenants are fully isolated: nothing is shared, so
  one family's drift or learning never perturbs another's.

* **Request coalescing** — concurrent ``select_cohort`` calls against
  the same tenant and embedding-table version are batched behind ONE
  engine entry: the first arrival becomes the batch *leader* and runs
  ``CohortServer.select_cohorts`` once; the batch stays open for
  joiners until the tenant's select lock is actually acquired (plus an
  optional ``batch_window_s`` pre-wait), so requests queuing behind an
  earlier solve ride the next batch together.  One fingerprint-cache-
  consistent :class:`~repro.cohort.CohortResult` is fanned out to every
  waiter, with the cluster pools partitioned across the batch so no
  client is double-served within it.  A table-version bump opens a new
  batch (requests against different versions never coalesce).

Synchronous callers lose nothing: with no concurrency a batch is just
one request and the path degenerates to ``select_cohort``.

* **Streaming** (``streaming=StreamingSpec(...)``) — the frontend owns
  one shared :class:`repro.streaming.BackgroundSolver` and
  :class:`repro.streaming.SolveDeduper` and wires every streaming
  tenant's server to them: embedding updates warm the next table
  version off the select path, identical-fingerprint tenants ride one
  solve, and per-tenant admission control (bounded in-flight depth +
  token-bucket rate) sheds overload with typed
  :class:`repro.streaming.ShedError`\\ s before it reaches the engine.
  ``close()`` (or the context manager) drains in-flight batches, joins
  the solver, and turns new selects into
  :class:`repro.streaming.ServiceClosedError`.

  PYTHONPATH=src python -m repro.launch.serve --cohort 20000 \
      --tenants 4 --cohort-size 64 --policy dqn --rounds 5 --streaming
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Iterable, List, Mapping, Optional, Tuple, Union

import numpy as np

from repro.launch.serve import CohortServer

#: default extra leader wait for followers, in seconds.  0 = rely on
#: natural batching alone: requests arriving while an earlier solve
#: holds the tenant's select lock coalesce into the next batch, and an
#: uncontended caller pays no added latency.  Set positive to also
#: coalesce bursty traffic that has no lock contention to queue behind.
DEFAULT_BATCH_WINDOW_S = 0.0


@dataclasses.dataclass
class TenantSpec:
    """Declarative description of one tenant shard (one model family).

    ``build()`` constructs the backing :class:`CohortServer`; every
    field after ``embed_dim`` mirrors the server's keyword of the same
    name.
    """
    name: str
    num_clients: int
    embed_dim: int
    config: Optional[object] = None       # CohortConfig
    seed: int = 0
    policy: str = "stratified"
    target_accuracy: float = 0.85
    dqn_overrides: Optional[dict] = None
    state_features: str = "rich"
    # repro.streaming.StreamingSpec; None inherits the frontend default
    streaming: Optional[object] = None

    def build(self, *, streaming=None, solver=None,
              deduper=None) -> CohortServer:
        return CohortServer(
            self.num_clients, self.embed_dim, config=self.config,
            seed=self.seed, policy=self.policy,
            target_accuracy=self.target_accuracy,
            dqn_overrides=self.dqn_overrides,
            state_features=self.state_features,
            streaming=self.streaming or streaming,
            solver=solver, deduper=deduper)


class _Batch:
    """One in-flight coalesced select batch for a (tenant, version)."""

    __slots__ = ("version", "sizes", "closed", "done", "results", "error")

    def __init__(self, version: int):
        self.version = version
        self.sizes: List[int] = []
        self.closed = False
        self.done = threading.Event()
        self.results = None
        self.error: Optional[BaseException] = None


class _Tenant:
    """A named shard plus its coalescing state.

    Request/batch totals live in the server's own counters (one source
    of truth — ``CohortServer.stats()``); the only frontend-level
    extra is ``max_batch``, the largest coalesced batch realized.
    """

    def __init__(self, name: str, server: CohortServer):
        self.name = name
        self.server = server
        self.lock = threading.Lock()
        self.open_batch: Optional[_Batch] = None    # guarded-by: lock
        self.max_batch = 0                          # guarded-by: lock
        # selects currently inside select_cohort (leader or joiner);
        # close() drains on this
        self.inflight = 0                           # guarded-by: lock


class CohortFrontend:
    """Multi-tenant, request-batching cohort-selection service.

    Args:
        tenants: initial shards — a mapping ``name -> CohortServer`` or
            an iterable of :class:`TenantSpec`; more can be added later
            with :meth:`add_tenant`.
        batch_window_s: extra time a batch leader waits for concurrent
            requests to join before solving.  The default ``0`` relies
            on natural batching (requests arriving while a previous
            solve holds the select lock coalesce into the next batch);
            positive values also coalesce bursts with no lock
            contention, at that much added latency per batch.
        streaming: default :class:`repro.streaming.StreamingSpec` for
            tenants built from :class:`TenantSpec`\\ s (a spec's own
            ``streaming`` field wins).  Streaming tenants share one
            frontend-owned background solver and solve deduper.
    """

    def __init__(self, tenants: Union[Mapping[str, CohortServer],
                                      Iterable[TenantSpec], None] = None,
                 *, batch_window_s: float = DEFAULT_BATCH_WINDOW_S,
                 streaming=None):
        self.batch_window_s = float(batch_window_s)
        self.streaming = streaming
        self._registry_lock = threading.Lock()
        self._tenants: Dict[str, _Tenant] = {}  # guarded-by: _registry_lock
        # shared across streaming tenants, created on first need
        self._solver = None                     # guarded-by: _registry_lock
        self._deduper = None                    # guarded-by: _registry_lock
        self._closed = False                    # guarded-by: _registry_lock
        if tenants is not None:
            if isinstance(tenants, Mapping):
                for name, server in tenants.items():
                    self.add_tenant(name, server)
            else:
                for spec in tenants:
                    self.add_tenant(spec.name, spec)

    # -- tenant registry --------------------------------------------------
    def _shared_streaming(self, spec):
        """The frontend-wide (solver, deduper) pair, created lazily."""
        from repro.streaming import BackgroundSolver, SolveDeduper
        with self._registry_lock:
            if self._solver is None:
                self._solver = BackgroundSolver(spec.solver_workers)
            if self._deduper is None and spec.dedupe:
                self._deduper = SolveDeduper()
            return self._solver, self._deduper if spec.dedupe else None

    def add_tenant(self, name: str,
                   server: Union[CohortServer, TenantSpec]) -> CohortServer:
        """Register a shard; returns its :class:`CohortServer`.

        A :class:`TenantSpec` builds its server here — with the
        frontend's shared background solver and deduper when the spec
        (or the frontend default) enables streaming.  A pre-built
        :class:`CohortServer` is registered as-is.
        """
        if isinstance(server, TenantSpec):
            spec = server.streaming or self.streaming
            solver = deduper = None
            if spec is not None:
                solver, deduper = self._shared_streaming(spec)
            server = server.build(streaming=spec, solver=solver,
                                  deduper=deduper)
        with self._registry_lock:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            self._tenants[name] = _Tenant(name, server)
        return server

    def _get(self, name: str) -> _Tenant:
        with self._registry_lock:
            try:
                return self._tenants[name]
            except KeyError:
                raise KeyError(
                    f"unknown tenant {name!r}; registered: "
                    f"{sorted(self._tenants)}") from None

    def tenant(self, name: str) -> CohortServer:
        """The backing :class:`CohortServer` of one shard."""
        return self._get(name).server

    @property
    def tenant_names(self) -> Tuple[str, ...]:
        with self._registry_lock:
            return tuple(self._tenants)

    # -- pass-throughs (per tenant, no coalescing needed) -----------------
    def update_embeddings(self, tenant: str, client_ids,
                          new_embeds) -> None:
        """Copy-on-write row update of one tenant's embedding table."""
        self._get(tenant).server.update_embeddings(client_ids, new_embeds)

    def observe_round(self, tenant: str, accuracy: float,
                      timings: Optional[dict] = None) -> float:
        """Report a completed round to one tenant; returns the reward."""
        return self._get(tenant).server.observe_round(accuracy, timings)

    # -- coalescing select ------------------------------------------------
    def select_cohort(self, tenant: str, cohort_size: int):
        """Serve one cohort from ``tenant``; returns ``(ids, result)``.

        Concurrent calls against the same tenant and table version
        coalesce: one caller (the leader) runs the engine once via
        ``CohortServer.select_cohorts`` and every waiter receives its
        own slice of the shared solve — cohorts within a batch are
        disjoint because they pop the same cluster pools.

        A streaming tenant's admission control runs first: past the
        configured in-flight depth or token-bucket rate the request is
        shed with a typed :class:`repro.streaming.ShedError` before any
        batching or engine work.  After :meth:`close`, selects raise
        :class:`repro.streaming.ServiceClosedError` instead.
        """
        if self._closed:
            from repro.streaming import ServiceClosedError
            raise ServiceClosedError("CohortFrontend is closed")
        t = self._get(tenant)
        adm = t.server.admission
        if adm is not None:
            adm.try_admit()                # raises ShedError on overload
        try:
            with t.lock:
                t.inflight += 1
                version = t.server.version
                batch = t.open_batch
                if (batch is not None and not batch.closed
                        and batch.version == version):
                    index = len(batch.sizes)
                    batch.sizes.append(int(cohort_size))
                    leader = False
                else:
                    batch = _Batch(version)
                    index = 0
                    batch.sizes.append(int(cohort_size))
                    t.open_batch = batch
                    leader = True
            try:
                if leader:
                    self._run_batch(t, batch)
                else:
                    batch.done.wait()
            finally:
                with t.lock:
                    t.inflight -= 1
        finally:
            if adm is not None:
                adm.release()
        if batch.error is not None:
            raise RuntimeError(
                f"coalesced select failed for tenant {t.name!r}"
            ) from batch.error
        return batch.results[index]

    def _run_batch(self, t: _Tenant, batch: _Batch) -> None:
        """Leader path: solve once for however many requests joined.

        The batch is sealed *inside* ``select_cohorts``, at the moment
        the tenant's select lock is actually acquired (``sizes_fn``
        callback) — so while an earlier batch's solve holds the lock,
        new arrivals keep coalescing into this one.  That is the natural
        batching that needs no waiting: an uncontended caller pays zero
        extra latency, a thundering herd rides one solve.  A positive
        ``batch_window_s`` adds an explicit pre-wait on top, for bursty
        traffic with no lock contention to lean on.
        """
        if self.batch_window_s > 0:
            time.sleep(self.batch_window_s)

        def seal() -> list:
            with t.lock:
                batch.closed = True        # no more joiners
                if t.open_batch is batch:
                    t.open_batch = None
                return list(batch.sizes)

        try:
            batch.results = t.server.select_cohorts(sizes_fn=seal)
            with t.lock:
                t.max_batch = max(t.max_batch, len(batch.results))
        except BaseException as exc:       # fan the failure out too
            batch.error = exc
        finally:
            with t.lock:                   # seal even on pre-seal failure
                batch.closed = True
                if t.open_batch is batch:
                    t.open_batch = None
            batch.done.set()

    # -- shutdown ---------------------------------------------------------
    def close(self, timeout: float = 30.0) -> None:
        """Graceful shutdown: reject, drain, join.  Idempotent.

        New ``select_cohort`` calls raise
        :class:`repro.streaming.ServiceClosedError` immediately;
        in-flight coalesced batches are drained (bounded by
        ``timeout`` seconds overall), the shared background solver is
        drained and joined, and every tenant server is closed.
        """
        with self._registry_lock:
            if self._closed:
                return
            self._closed = True
            tenants = dict(self._tenants)
            solver = self._solver
        deadline = time.monotonic() + timeout
        for t in tenants.values():
            while True:
                with t.lock:
                    idle = t.inflight == 0 and t.open_batch is None
                if idle or time.monotonic() >= deadline:
                    break
                time.sleep(0.002)
        # tenant servers share the frontend's solver, so closing them
        # only flips their reject flag; the solver joins once, here
        for t in tenants.values():
            t.server.close()
        if solver is not None:
            solver.close(timeout=max(0.0, deadline - time.monotonic()))

    def __enter__(self) -> "CohortFrontend":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # -- observability ----------------------------------------------------
    def stats(self) -> dict:
        """Aggregate + per-tenant serving stats.

        ``tenants`` maps each shard name to its full
        ``CohortServer.stats()`` dict plus ``max_batch`` (largest
        coalesced batch realized); ``frontend`` aggregates across
        shards — request/batch/solve totals come straight from the
        servers' own counters (single source of truth), and
        ``batch_factor = requests / batches`` is the mean realized
        coalescing per engine entry.  The streaming counters aggregate
        too: ``warm_ahead`` / ``served_warm`` / ``forced_inline`` /
        ``dedupe_hit`` / ``shed`` summed across shards.
        """
        with self._registry_lock:
            tenants = dict(self._tenants)
        per_tenant = {}
        agg = {"num_tenants": len(tenants), "requests": 0, "solves": 0,
               "cache_hits": 0, "batches": 0, "max_batch": 0,
               "rounds_observed": 0, "warm_ahead": 0, "served_warm": 0,
               "forced_inline": 0, "dedupe_hit": 0, "shed": 0}
        for name, t in tenants.items():
            st = t.server.stats()
            with t.lock:
                st["max_batch"] = t.max_batch
            per_tenant[name] = st
            agg["requests"] += st["requests"]
            agg["batches"] += st["batches"]
            agg["rounds_observed"] += st["rounds_observed"]
            agg["solves"] += st["engine"]["solves"]
            agg["cache_hits"] += st["engine"]["cache_hits"]
            agg["max_batch"] = max(agg["max_batch"], st["max_batch"])
            for key in ("warm_ahead", "served_warm", "forced_inline",
                        "dedupe_hit", "shed"):
                agg[key] += st[key]
        agg["batch_factor"] = agg["requests"] / max(agg["batches"], 1)
        return {"frontend": agg, "tenants": per_tenant}


def make_demo_frontend(num_tenants: int, num_clients: int, embed_dim: int,
                       *, config=None, seed: int = 0,
                       policy: str = "stratified",
                       batch_window_s: float = DEFAULT_BATCH_WINDOW_S,
                       streaming=None) -> CohortFrontend:
    """Frontend with ``num_tenants`` synthetic model-family shards.

    Tenant ``family-i`` gets an independent seed (``seed + i``) so the
    shards' engines, draw rngs, and Q-networks are decorrelated — the
    isolation the tenant tests pin down.  ``streaming`` (a
    :class:`repro.streaming.StreamingSpec`) applies to every shard.
    """
    specs = [TenantSpec(f"family-{i}", num_clients, embed_dim,
                        config=config, seed=seed + i, policy=policy)
             for i in range(num_tenants)]
    return CohortFrontend(specs, batch_window_s=batch_window_s,
                          streaming=streaming)


def run_demo(args) -> None:
    """`--cohort N --tenants T` CLI mode: concurrent multi-tenant serving.

    Spins up T tenant shards of N synthetic clients each and fires
    ``args.rounds`` waves of concurrent select requests (one thread per
    client worker, round-robin over tenants), reporting the realized
    coalescing factor and per-tenant serving stats.
    """
    import json

    from repro.cohort import CohortConfig

    rng = np.random.default_rng(args.seed)
    d = 8
    num_landmarks = args.num_landmarks
    if num_landmarks not in (None, "auto"):
        num_landmarks = int(num_landmarks)
    cfg = CohortConfig(num_clusters=args.num_clusters,
                       landmarks=args.landmarks,
                       num_landmarks=num_landmarks)
    streaming = None
    if getattr(args, "streaming", False):
        from repro.streaming import StreamingSpec
        streaming = StreamingSpec(max_stale_versions=args.max_stale)
    fe = make_demo_frontend(args.tenants, args.cohort, d, config=cfg,
                            seed=args.seed, policy=args.policy,
                            batch_window_s=args.batch_window,
                            streaming=streaming)
    for name in fe.tenant_names:
        centers = rng.normal(size=(args.num_clusters, d)) * 6
        labels = rng.integers(0, args.num_clusters, args.cohort)
        fe.update_embeddings(
            name, np.arange(args.cohort),
            (centers[labels]
             + rng.normal(size=(args.cohort, d))).astype(np.float32))

    workers = max(args.concurrency, 1)
    for r in range(args.rounds):
        t0 = time.perf_counter()
        threads = []
        for w in range(workers):
            name = fe.tenant_names[w % len(fe.tenant_names)]
            th = threading.Thread(
                target=fe.select_cohort, args=(name, args.cohort_size))
            th.start()
            threads.append(th)
        for th in threads:
            th.join()
        dt = time.perf_counter() - t0
        for name in fe.tenant_names:
            fe.observe_round(name, 0.5 + 0.1 * rng.random())
        agg = fe.stats()["frontend"]
        print(f"round {r}: {workers} concurrent selects over "
              f"{args.tenants} tenants in {dt:.3f}s "
              f"({workers / max(dt, 1e-9):,.1f} selects/s, "
              f"batch factor {agg['batch_factor']:.2f})")
    fe.close()
    print("frontend stats:", json.dumps(fe.stats()["frontend"], indent=2,
                                        default=float))
