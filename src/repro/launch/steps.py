"""Step builders + input specs for every (arch × workload shape).

``build_step(cfg, shape, mesh)`` returns everything the dry-run, trainer
and server need: the step function, ShapeDtypeStruct stand-ins for every
input (weak-type-correct, shardable, zero allocation), and the
in/out shardings assembled from the rule engine.

Workload -> step mapping:
  train_4k                -> train_step   (grad-accum microbatches + AdamW)
  prefill_32k             -> prefill_step (forward + KV-cache fill)
  decode_32k / long_500k  -> serve_step   (ONE token against a seq_len cache)

long_500k on pure-attention archs uses the sliding-window variant
(cfg.long_context_window) — the sub-quadratic requirement; SSM/hybrid
archs carry O(1)/O(S_attn) state natively (DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.models.sharding import (batch_pspec, data_axes, params_pspecs,
                                   use_mesh)
from repro.optim import adamw, clip_by_global_norm, linear_warmup_cosine

# ---------------------------------------------------------------------------
# Microbatch policy (activation-memory control; see EXPERIMENTS.md §Dry-run)
# ---------------------------------------------------------------------------

# tuned in §Perf iteration H4 so every train combo fits 16 GiB/device
# (see EXPERIMENTS.md §Perf for the before/after peak-bytes table).
_MICROBATCHES = {
    ("deepseek-v3-671b", "train_4k"): 32,
    ("jamba-v0.1-52b", "train_4k"): 16,
    ("llama4-scout-17b-a16e", "train_4k"): 16,
    ("internvl2-26b", "train_4k"): 8,
    ("qwen3-14b", "train_4k"): 8,
    ("qwen2-7b", "train_4k"): 4,
    ("moonshot-v1-16b-a3b", "train_4k"): 16,
    ("mamba2-2.7b", "train_4k"): 8,
    ("gemma-2b", "train_4k"): 2,
    ("seamless-m4t-medium", "train_4k"): 2,
}


def num_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                     dp: int = 1) -> int:
    """Gradient-accumulation factor, clamped so each microbatch still
    shards evenly over the ``dp`` data-parallel ways (a fractional
    per-shard batch forces GSPMD into full rematerialization — observed
    as 'Involuntary full rematerialization' warnings in §Perf H4)."""
    if shape.kind != "train":
        return 1
    g = _MICROBATCHES.get((cfg.name, shape.name), shape.num_microbatches)
    g = max(1, min(g, shape.global_batch // max(dp, 1) or 1))
    while shape.global_batch % (g * max(dp, 1)):
        g -= 1
    return max(g, 1)


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> Optional[int]:
    """Sliding window for long-context decode on pure-attention archs."""
    if shape.name == "long_500k" and cfg.arch_type in ("dense", "moe", "vlm"):
        return cfg.long_context_window
    return None


# ---------------------------------------------------------------------------
# Input ShapeDtypeStructs
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for one global batch of this workload."""
    B, S = shape.global_batch, shape.seq_len
    cdt = cfg.compute_dtype
    if cfg.is_encoder_decoder:
        return {"src_embeds": _sds((B, S, cfg.d_model), cdt),
                "tokens": _sds((B, S), jnp.int32),
                "labels": _sds((B, S), jnp.int32)}
    out = {}
    n_text = S - cfg.num_prefix_embeds
    out["tokens"] = _sds((B, n_text), jnp.int32)
    out["labels"] = _sds((B, n_text), jnp.int32)
    if cfg.num_prefix_embeds:
        out["prefix_embeds"] = _sds((B, cfg.num_prefix_embeds, cfg.d_model),
                                    cdt)
    return out


def params_specs(cfg: ModelConfig):
    init = ED.init_encdec if cfg.is_encoder_decoder else T.init_lm
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    if cfg.is_encoder_decoder:
        return jax.eval_shape(
            lambda: ED.init_encdec_cache(cfg, batch, max_seq))
    return jax.eval_shape(lambda: T.init_lm_cache(cfg, batch, max_seq))


# ---------------------------------------------------------------------------
# Cache sharding rules (DESIGN.md §4)
# ---------------------------------------------------------------------------


def cache_pspecs(cache, mesh: Mesh, batch: int):
    """Leaves are stacked (layers, B, ...).  batch -> (pod,data) when it
    divides; the cache *sequence* dim -> 'model' (flash-decode style
    partial-softmax sharding); SSM state heads / conv channels -> 'model'."""
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    msize = mesh.shape.get("model", 1)

    def spec_for(path, leaf):
        name = path.split("/")[-1]
        nd = leaf.ndim
        spec = [None] * nd
        batch_ok = batch % dsize == 0
        if batch_ok:
            spec[1] = daxes
        if name in ("k", "v", "ckv", "krope"):
            seq = leaf.shape[2]
            if batch_ok:
                if seq % msize == 0:
                    spec[2] = "model"
            else:
                # batch=1 long-context: shard seq over every axis it divides
                full = (*daxes, "model")
                if seq % int(np.prod([mesh.shape[a] for a in full])) == 0:
                    spec[2] = full
                elif "data" in mesh.axis_names and seq % mesh.shape["data"] == 0:
                    spec[2] = "data"
            if (name in ("k", "v") and spec[2] is None
                    and leaf.shape[3] % msize == 0):
                spec[3] = "model"
        elif name == "ssm":
            if leaf.shape[2] % msize == 0:
                spec[2] = "model"
        elif name == "conv":
            if leaf.shape[3] % msize == 0:
                spec[3] = "model"
        return P(*spec)

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, f"{path}/{i}")
                              for i, v in enumerate(node))
        return spec_for(path, node)

    return walk(cache, "")


def _named(mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(cfg, shape, mesh):
    specs = batch_specs(cfg, shape)
    return {k: NamedSharding(mesh, batch_pspec(mesh, v.ndim, 0,
                                               shape.global_batch))
            for k, v in specs.items()}


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def make_optimizer(cfg: ModelConfig, total_steps: int = 10_000,
                   state_dtype: Optional[str] = None):
    # bf16 moments for the very large configs (fits one pod; DESIGN.md §4)
    if state_dtype is None:
        state_dtype = "bfloat16" if cfg.param_count() > 5e10 else "float32"
    return adamw(linear_warmup_cosine(3e-4, 200, total_steps),
                 state_dtype=state_dtype)


def make_train_step(cfg: ModelConfig, shape: ShapeConfig, opt,
                    dp: int = 1) -> Callable:
    G = num_microbatches(cfg, shape, dp)
    loss_fn = (ED.encdec_train_loss if cfg.is_encoder_decoder
               else T.lm_train_loss)

    def train_step(params, opt_state, step, batch):
        def grad_fn(mb):
            return jax.value_and_grad(
                lambda p: loss_fn(p, cfg, mb), has_aux=True)(params)

        # H8: very large models accumulate grads in bf16 (Switch-style) —
        # the f32 accumulator for 656B expert params alone was 10 GiB/dev.
        acc_dtype = (jnp.bfloat16 if cfg.param_count() > 5e10
                     else jnp.float32)
        if G == 1:
            (loss, metrics), grads = grad_fn(batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape(G, x.shape[0] // G, *x.shape[1:]), batch)
            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                                params)

            def body(acc, mb):
                (loss, metrics), grads = grad_fn(mb)
                acc = jax.tree.map(
                    lambda a, g: a + (g.astype(jnp.float32) / G
                                      ).astype(a.dtype), acc, grads)
                return acc, metrics

            grads, ms = jax.lax.scan(body, acc0, mbs)
            metrics = jax.tree.map(jnp.mean, ms)

        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(grads, opt_state, params, step)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig) -> Callable:
    def prefill_step(params, batch):
        if cfg.is_encoder_decoder:
            caches = ED.init_encdec_cache(cfg, shape.global_batch,
                                          shape.seq_len)
            return ED.encdec_prefill(params, cfg, batch, caches)
        caches = T.init_lm_cache(cfg, shape.global_batch, shape.seq_len)
        return T.lm_prefill(params, cfg, batch, caches)

    return prefill_step


def make_decode_step(cfg: ModelConfig, shape: ShapeConfig) -> Callable:
    window = decode_window(cfg, shape)

    def serve_step(params, caches, token, pos):
        if cfg.is_encoder_decoder:
            return ED.encdec_decode_step(params, cfg, token, caches, pos,
                                         window=window)
        return T.lm_decode_step(params, cfg, token, caches, pos,
                                window=window)

    return serve_step


# ---------------------------------------------------------------------------
# Bundles for the dry-run / launchers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepBundle:
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    static_names: Tuple[str, ...] = ()


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               total_steps: int = 10_000) -> StepBundle:
    p_specs = params_specs(cfg)
    p_shard = _named(mesh, params_pspecs(p_specs, mesh))
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt = make_optimizer(cfg, total_steps)
        opt_specs = jax.eval_shape(opt.init, p_specs)
        opt_shard = _named(mesh, params_pspecs(opt_specs, mesh))
        b_specs = batch_specs(cfg, shape)
        b_shard = batch_shardings(cfg, shape, mesh)
        dp = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
        fn = make_train_step(cfg, shape, opt, dp)
        args = (p_specs, opt_specs, _sds((), jnp.int32), b_specs)
        in_sh = (p_shard, opt_shard, repl, b_shard)
        out_sh = (p_shard, opt_shard, None)
        # H10 (REFUTED on the CPU dry-run backend, see EXPERIMENTS.md §Perf):
        # donating params+opt is correct on TPU, but XLA:CPU's buffer
        # assignment regressed temp 24->40 GiB with aliasing enabled, so
        # the dry-run measures without donation.  Flip on real hardware:
        return StepBundle(fn, args, in_sh, out_sh, donate_argnums=())

    if shape.kind == "prefill":
        b_specs = batch_specs(cfg, shape)
        b_specs.pop("labels", None)
        b_shard = batch_shardings(cfg, shape, mesh)
        b_shard.pop("labels", None)
        c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len)
        c_shard = _named(mesh, cache_pspecs(c_specs, mesh,
                                            shape.global_batch))
        fn = make_prefill_step(cfg, shape)
        args = (p_specs, b_specs)
        in_sh = (p_shard, b_shard)
        out_sh = (None, c_shard)
        return StepBundle(fn, args, in_sh, out_sh)

    # decode
    c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len)
    c_shard = _named(mesh, cache_pspecs(c_specs, mesh, shape.global_batch))
    tok = _sds((shape.global_batch, 1), jnp.int32)
    tok_shard = NamedSharding(mesh, batch_pspec(mesh, 2, 0,
                                                shape.global_batch))
    fn = make_decode_step(cfg, shape)
    # Continuous-batching serving decodes with per-request cache
    # positions (B,) so every row masks its own [0, pos[i]] prefix.
    # Encoder-decoder and windowed long-context decode keep the scalar
    # lockstep position: encdec decode has no slot table, and the H3
    # windowed cache-slice optimisation needs a scalar slice start
    # (long_500k is batch=1, so nothing is lost).
    if cfg.is_encoder_decoder or decode_window(cfg, shape) is not None:
        pos_spec = _sds((), jnp.int32)
        pos_shard = repl
    else:
        pos_spec = _sds((shape.global_batch,), jnp.int32)
        pos_shard = NamedSharding(mesh, batch_pspec(mesh, 1, 0,
                                                    shape.global_batch))
    args = (p_specs, c_specs, tok, pos_spec)
    in_sh = (p_shard, c_shard, tok_shard, pos_shard)
    out_sh = (None, c_shard)
    # H10 (REFUTED on CPU backend — see train bundle note): cache donation
    # is the production setting on TPU; measured OFF here.
    return StepBundle(fn, args, in_sh, out_sh, donate_argnums=())


def lower_step(bundle: StepBundle, mesh: Mesh):
    """AOT-lower the bundle on ``mesh`` (no allocation)."""
    with use_mesh(mesh):
        jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.args)
    return lowered
