import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh).

The two lines above MUST run before any jax import (jax locks the device
count at first init) — which is why this module sets XLA_FLAGS at the very
top and why nothing else in the package does.

For each combination this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. assembles the step bundle (ShapeDtypeStruct inputs + rule-engine
     shardings — zero device allocation),
  3. ``jax.jit(step).lower(...).compile()``,
  4. records ``memory_analysis()`` (proves it fits), ``cost_analysis()``
     (FLOPs/bytes for §Roofline) and the per-collective byte counts parsed
     from the optimized HLO,
  5. writes a JSON record under experiments/dryrun/ that the roofline
     benchmark (§Roofline) and EXPERIMENTS.md tables read.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback


def _run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             verbose: bool = True) -> dict:
    import jax
    from repro.configs import get_config, get_shape
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_step, lower_step
    from repro.roofline.analysis import (collective_bytes_from_hlo,
                                         extract_cost, roofline_report)

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "num_devices": mesh.size, "status": "ok"}
    t0 = time.time()
    try:
        bundle = build_step(cfg, shape, mesh)
        lowered = lower_step(bundle, mesh)
        rec["lower_seconds"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_seconds"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        }
        rec["cost"] = extract_cost(compiled)
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes_from_hlo(hlo)
        # HLO-derived terms (CAVEAT: XLA counts scan bodies once — these
        # under-report for scanned layers; kept as secondary evidence).
        rec["roofline_hlo"] = roofline_report(cfg, shape, mesh, rec)
        # Primary analytic roofline (EXPERIMENTS.md §Roofline/methodology).
        import numpy as _np
        from repro.launch.steps import num_microbatches
        from repro.models.sharding import data_axes
        from repro.roofline.calculator import roofline_terms
        dp = int(_np.prod([mesh.shape[a] for a in data_axes(mesh)]))
        rec["roofline"] = roofline_terms(
            cfg, shape, mesh, num_microbatches(cfg, shape, dp))
        if verbose:
            m = rec["memory"]
            r = rec["roofline"]
            print(f"[ok] {arch} x {shape_name} x {mesh_name}: "
                  f"args {m['argument_bytes']/2**30:.2f} GiB/dev, "
                  f"temp {(m['temp_bytes'] or 0)/2**30:.2f} GiB/dev | "
                  f"compute {r['compute_s']*1e3:.2f} ms, "
                  f"memory {r['memory_s']*1e3:.2f} ms, "
                  f"collective {r['collective_s']*1e3:.2f} ms "
                  f"-> {r['bottleneck']}-bound "
                  f"(lower {rec['lower_seconds']}s, "
                  f"compile {rec['compile_seconds']}s)")
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {mesh_name}: {rec['error']}")

    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        slim = {k: v for k, v in rec.items() if k != "traceback"}
        json.dump(slim, f, indent=2, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) combination")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import SHAPES, list_archs

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                path = os.path.join(args.out,
                                    f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") == "ok":
                            print(f"[skip] {arch} x {shape} x {mesh_name}")
                            continue
                rec = _run_one(arch, shape, multi, args.out)
                failures += rec["status"] != "ok"
    print(f"\ndry-run complete; {failures} failure(s)")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
