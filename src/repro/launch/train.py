"""Training launcher: distributed LM training or federated (FL) training.

Standard mode runs the data-parallel/tensor-parallel training loop over the
synthetic token pipeline with checkpointing.  ``--fl`` runs the paper's
federated workflow: DQRE-SCnet (or a baseline policy) selects the cohort
every communication round (examples/fl_mnist.py is the scripted variant).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --steps 20 \
      --reduced --global-batch 8 --seq-len 128
  PYTHONPATH=src python -m repro.launch.train --fl --dataset mnist \
      --policy dqre_sc --rounds 30
"""

from __future__ import annotations

import argparse
import dataclasses
import time


def train_lm(args) -> None:
    import jax
    import numpy as np
    from repro.checkpoint import Checkpointer
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.data import TokenDataConfig, make_batch_iterator
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import (build_step, lower_step, make_optimizer,
                                    make_train_step)
    from repro.models import transformer as T
    from repro.models import encdec as ED
    from repro.models.sharding import use_mesh

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("custom_train", args.seq_len, args.global_batch,
                        "train", args.microbatches)

    n_dev = len(jax.devices())
    mesh = make_test_mesh(data=n_dev, model=1)
    opt = make_optimizer(cfg, args.steps)
    step_fn = make_train_step(cfg, shape, opt)

    key = jax.random.PRNGKey(args.seed)
    init = ED.init_encdec if cfg.is_encoder_decoder else T.init_lm
    with use_mesh(mesh):
        params = init(key, cfg)
        opt_state = opt.init(params)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M devices={n_dev}")

    data_cfg = TokenDataConfig(cfg.vocab_size, args.seq_len,
                               args.global_batch, seed=args.seed)
    it = make_batch_iterator(data_cfg, mesh, num_batches=args.steps)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    jitted = jax.jit(step_fn)
    t0 = time.time()
    for step, batch in enumerate(it):
        if cfg.is_encoder_decoder:
            bsz = batch["tokens"].shape[0]
            batch = dict(batch, src_embeds=jax.numpy.zeros(
                (bsz, args.seq_len, cfg.d_model), cfg.compute_dtype))
        params, opt_state, metrics = jitted(
            params, opt_state, jax.numpy.int32(step), batch)
        if step % args.log_every == 0:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            tok_s = args.global_batch * args.seq_len * (step + 1) / dt
            print(f"step {step:5d}  loss {loss:.4f}  {tok_s:,.0f} tok/s")
        if ckpt and step and step % args.ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt_state": opt_state},
                      {"loss": float(metrics['loss'])})
    print(f"done in {time.time()-t0:.1f}s; final loss "
          f"{float(metrics['loss']):.4f}")
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt_state": opt_state})


def train_fl(args) -> None:
    from repro.fed import FederatedRunner, RunnerConfig

    cfg = RunnerConfig(dataset=args.dataset, policy=args.policy,
                       sigma=args.sigma, num_clients=args.num_clients,
                       clients_per_round=args.clients_per_round,
                       target_accuracy=args.target_accuracy, seed=args.seed)
    runner = FederatedRunner(cfg)
    print(f"FL: {args.dataset} sigma={args.sigma} policy={args.policy} "
          f"clients={args.num_clients} cohort={args.clients_per_round}")
    for _ in range(args.rounds):
        res = runner.run_round()
        print(f"round {res.round_idx:4d}  acc {res.accuracy:.4f}  "
              f"reward {res.reward:+.3f}  ({res.seconds:.1f}s)")
        if res.accuracy >= args.target_accuracy:
            print(f"target {args.target_accuracy} reached at round "
                  f"{res.round_idx + 1}")
            break
    print("final metrics:", runner.final_metrics())


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fl", action="store_true")
    # LM mode
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    # FL mode
    ap.add_argument("--dataset", default="mnist")
    ap.add_argument("--policy", default="dqre_sc",
                    choices=["fedavg", "kcenter", "favor", "dqre_sc"])
    ap.add_argument("--sigma", type=float, default=0.5)
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--num-clients", type=int, default=100)
    ap.add_argument("--clients-per-round", type=int, default=10)
    ap.add_argument("--target-accuracy", type=float, default=0.85)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    (train_fl if args.fl else train_lm)(args)


if __name__ == "__main__":
    main()
