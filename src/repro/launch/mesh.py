"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — essential because the dry-run
forces 512 placeholder host devices while tests/benches must see 1.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """TPU v5e target: 16x16 (256 chips) per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 1) -> Mesh:
    """Small mesh over however many (possibly forced-host) devices exist."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_cohort_mesh(num_devices: int | None = None) -> Mesh:
    """1-D mesh for the sharded cohort-selection engine.

    The distributed Nyström path shards CLIENT ROWS over the single
    ``"clients"`` axis (the m-sized landmark problem is replicated), so
    the cohort mesh is flat over every visible device — on a TPU pod
    that is all chips; under ``--xla_force_host_platform_device_count``
    the forced host devices.
    """
    n = num_devices or len(jax.devices())
    return jax.make_mesh((n,), ("clients",))


def device_count_available(n: int) -> bool:
    return len(jax.devices()) >= n
