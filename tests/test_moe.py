import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import moe as MOE
from repro.models import layers as L

KEY = jax.random.PRNGKey(0)


def mk_cfg(**kw):
    base = dict(name="t", arch_type="moe", num_layers=1, d_model=16,
                num_heads=2, num_kv_heads=2, head_dim=8, d_ff=32,
                vocab_size=64, num_experts=4, experts_per_token=2,
                moe_d_ff=32, param_dtype="float32", compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_moe_shapes_and_finite():
    cfg = mk_cfg()
    p = MOE.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, 16))
    out, metrics = MOE.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert metrics["moe_aux_loss"] > 0


def test_small_batch_is_lossless():
    """Below the lossless threshold no token may be dropped."""
    cfg = mk_cfg()
    p = MOE.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (4, 16, 16))
    _, metrics = MOE.moe_apply(p, x, cfg)
    assert float(metrics["moe_dropped_frac"]) == 0.0


def test_top1_matches_manual_dense_computation():
    """With top-1 routing and no drops, the MoE output must equal running
    each token through its argmax expert scaled by prob 1.0."""
    cfg = mk_cfg(experts_per_token=1, num_shared_experts=0)
    p = MOE.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 8, 16))
    out, _ = MOE.moe_apply(p, x, cfg)

    xf = x.reshape(-1, 16)
    logits = xf @ p["router"]["w"]
    assign = np.asarray(jnp.argmax(logits, axis=-1))
    we = p["experts"]
    ref = np.zeros_like(np.asarray(xf))
    for t, e in enumerate(assign):
        h = np.asarray(jax.nn.silu(xf[t] @ we["gate"][e])) \
            * np.asarray(xf[t] @ we["up"][e])
        ref[t] = h @ np.asarray(we["down"][e])
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 16)), ref,
                               atol=1e-4)


def test_shared_expert_added():
    cfg = mk_cfg(num_shared_experts=1)
    p = MOE.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 8, 16))
    out_with, _ = MOE.moe_apply(p, x, cfg)
    p2 = dict(p)
    p2.pop("shared")
    cfg2 = mk_cfg(num_shared_experts=0)
    out_without, _ = MOE.moe_apply(p2, x, cfg2)
    shared = L.mlp(p["shared"], x.reshape(-1, 16), act=cfg.mlp_act)
    np.testing.assert_allclose(np.asarray(out_with),
                               np.asarray(out_without)
                               + np.asarray(shared).reshape(1, 8, 16),
                               atol=1e-5)


def test_capacity_drops_when_forced():
    """A skewed router (all tokens -> one expert) with a large batch must
    drop tokens at capacity."""
    cfg = mk_cfg(capacity_factor=1.0)
    p = MOE.moe_init(KEY, cfg)
    # bias router to a single expert
    w = np.zeros((16, 4), np.float32)
    w[:, 0] = 10.0
    p["router"]["w"] = jnp.asarray(w)
    x = jax.random.normal(KEY, (8, 512, 16))       # 4096 tokens x k=2 > 4096
    _, metrics = MOE.moe_apply(p, x, cfg)
    # expert 0 receives 4096 assignments but capacity = T*k*cf/E = 2048,
    # so exactly (4096-2048)/8192 = 25% of assignments drop.
    assert float(metrics["moe_dropped_frac"]) == pytest.approx(0.25,
                                                               abs=0.03)


def test_aux_loss_prefers_balance():
    cfg = mk_cfg()
    p = MOE.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, 16))
    _, m_balanced = MOE.moe_apply(p, x, cfg)
    w = np.zeros((16, 4), np.float32)
    w[:, 1] = 10.0
    p["router"]["w"] = jnp.asarray(w)
    _, m_skewed = MOE.moe_apply(p, x, cfg)
    assert float(m_skewed["moe_aux_loss"]) > float(m_balanced["moe_aux_loss"])
