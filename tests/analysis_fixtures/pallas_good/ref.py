"""Fixture: sibling oracle for pallas_good/kernel_pallas.py."""


def scale_ref(x):
    return x * 2.0
