"""Fixture: a compliant Pallas wrapper (parsed, not run)."""
import functools

import jax
from jax.experimental import pallas as pl


def _scale_kernel(x_ref, o_ref, *, factor):
    o_ref[...] = x_ref[...] * factor


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def scale_pallas(x, *, block_rows: int = 128, interpret: bool = False):
    grid = (x.shape[0] // block_rows,)
    return pl.pallas_call(
        functools.partial(_scale_kernel, factor=2.0),
        grid=grid,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
