"""Fixture: violations silenced by inline suppressions (parsed, not run)."""
import time

import jax
import numpy as np


@jax.jit
def same_line(x):
    t = time.time()  # repro-lint: ignore[jax-host-time] fixture rationale
    return x + t


@jax.jit
def line_above(x):
    # repro-lint: ignore[prng-constant-key]
    key = jax.random.PRNGKey(0)
    return x + jax.random.normal(key, x.shape)


@jax.jit
def blanket(x):
    noise = np.random.rand()  # repro-lint: ignore
    return x + noise


@jax.jit
def wrong_rule_listed(x):
    # a suppression for a DIFFERENT rule must not silence this one
    t = time.time()  # repro-lint: ignore[prng-key-reuse]
    return x + t
