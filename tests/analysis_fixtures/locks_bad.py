"""Fixture: lock-discipline violations (parsed, not run).

* ``unguarded_mutation`` writes a ``# guarded-by:`` attribute without
  holding its lock (``lock-guarded-by``).
* ``ab`` / ``ba`` acquire the two locks in opposite orders
  (``lock-order-cycle``).
"""
import threading


class BadServer:
    def __init__(self):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()
        self._state = {}                  # guarded-by: _a_lock

    def unguarded_mutation(self, key, value):
        self._state[key] = value          # mutated without _a_lock

    def unguarded_mutator_call(self, other):
        self._state.update(other)         # container mutator, no lock

    def ab(self):
        with self._a_lock:
            with self._b_lock:
                return len(self._state)

    def ba(self):
        with self._b_lock:
            with self._a_lock:
                self._state.clear()       # held, so not a guard finding
