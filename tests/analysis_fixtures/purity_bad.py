"""Fixture: every purity/PRNG rule violated once (parsed, not run)."""
import random
import time

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def host_time(x):
    t = time.time()                      # jax-host-time
    return x + t


@jax.jit
def host_random(x):
    noise = np.random.normal(size=3)     # jax-host-random
    r = random.random()                  # jax-host-random (stdlib)
    return x + noise + r


@jax.jit
def host_sync(x):
    a = x.item()                         # jax-host-sync
    b = float(x)                         # jax-host-sync
    c = np.asarray(x)                    # jax-host-sync
    return a + b + c.sum()


@jax.jit
def constant_key(x):
    key = jax.random.PRNGKey(0)          # prng-constant-key
    noise = jax.random.normal(jax.random.PRNGKey(1), x.shape)  # also
    return x + jax.random.normal(key, x.shape) + noise


@jax.jit
def key_reuse(key, x):
    a = jax.random.normal(key, x.shape)
    b = jax.random.uniform(key, x.shape)  # prng-key-reuse
    return x + a + b


@jax.jit
def reaches_helper(x):
    return _helper(x)


def _helper(x):
    # reachable from the jitted root above -> still traced code
    return x * time.perf_counter()       # jax-host-time


@jax.jit
def _scalar_loss(x):
    return jnp.sum(x * x)


def hot_path(x):
    loss = _scalar_loss(x)
    return float(loss)                   # jax-blocking-sync
