"""Fixture: sibling ref.py WITHOUT the shift_ref oracle."""


def unrelated_ref(x):
    return x
