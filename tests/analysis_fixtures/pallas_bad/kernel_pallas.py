"""Fixture: a non-compliant Pallas wrapper (parsed, not run).

Violates all three kernel rules: no ``interpret=`` plumbing, block size
not declared static, and no ``shift_ref`` oracle in the sibling ref.py.
"""
import functools

import jax
from jax.experimental import pallas as pl


def _shift_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1.0


def shift_pallas(x, *, block_rows: int = 128):
    grid = (x.shape[0] // block_rows,)
    return pl.pallas_call(
        _shift_kernel,
        grid=grid,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
    )(x)
