"""Fixture: lock discipline done right (parsed, not run)."""
import threading


class GoodServer:
    def __init__(self):
        self._table_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._table = {}                  # guarded-by: _table_lock
        self._counters = {"hits": 0}      # guarded-by: _stats_lock

    def write(self, key, value):
        with self._table_lock:
            self._table[key] = value
        # consistent global order: _table_lock before _stats_lock
        with self._stats_lock:
            self._counters["hits"] += 1

    def nested(self, key, value):
        with self._table_lock:
            self._table[key] = value
            self._bump()                  # callee takes the inner lock

    def _bump(self):
        with self._stats_lock:
            self._counters["hits"] += 1

    def read(self):
        with self._stats_lock:
            return dict(self._counters)
