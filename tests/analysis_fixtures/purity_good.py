"""Fixture: the same shapes as purity_bad, done right (parsed, not run)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def timestamp_as_arg(x, now):
    # the clock value is threaded in by the caller, not read in-trace
    return x + now


@jax.jit
def device_random(key, x):
    # key enters as a parameter; derived keys come from split/fold_in
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, x.shape)
    b = jax.random.uniform(k2, x.shape)
    k3 = jax.random.fold_in(key, 7)
    return x + a + b + jax.random.normal(k3, x.shape)


@jax.jit
def stays_on_device(x):
    # no .item()/float()/np.asarray(): everything stays jnp
    return jnp.sum(x.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("scale",))
def static_concretization(x, scale):
    # float() on a static argname is trace-time Python, not a sync
    return x * float(scale)


def outside_trace(x):
    # host-side code may use host RNG and materialize freely
    rng = np.random.default_rng(0)
    return float(np.sum(x)) + rng.random()


def observed_loss(agent):
    # deferred materialization: the jitted result was stored earlier
    # and is only converted at the observation point
    return agent.last_loss
