import numpy as np

from repro.data import TokenDataConfig, make_batch_iterator, \
    synthetic_token_batches


def test_batch_shapes_and_label_shift():
    cfg = TokenDataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=1)
    batch = next(synthetic_token_batches(cfg, 1))
    assert batch["tokens"].shape == (4, 16)
    assert batch["labels"].shape == (4, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["labels"][:, :-1])
    assert batch["tokens"].max() < 100


def test_stream_determinism():
    cfg = TokenDataConfig(vocab_size=50, seq_len=8, global_batch=2, seed=7)
    a = [b["tokens"] for b in synthetic_token_batches(cfg, 3)]
    b = [b["tokens"] for b in synthetic_token_batches(cfg, 3)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_markov_structure_is_learnable():
    """The stream must be predictable above chance (Markov structure)."""
    cfg = TokenDataConfig(vocab_size=64, seq_len=256, global_batch=8, seed=0)
    batch = next(synthetic_token_batches(cfg, 1))
    toks = batch["tokens"]
    # bigram predictability: most-frequent successor accuracy
    from collections import Counter, defaultdict
    succ = defaultdict(Counter)
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ[int(a)][int(b)] += 1
    correct = total = 0
    for a, counter in succ.items():
        best = counter.most_common(1)[0][1]
        correct += best
        total += sum(counter.values())
    assert correct / total > 3.0 / 64          # far above uniform chance


def test_iterator_prefetch_completes():
    cfg = TokenDataConfig(vocab_size=32, seq_len=8, global_batch=2, seed=0)
    batches = list(make_batch_iterator(cfg, mesh=None, num_batches=3))
    assert len(batches) == 3
    assert batches[0]["tokens"].shape == (2, 8)
