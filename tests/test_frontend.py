"""Multi-tenant frontend: coalescing, tenant isolation, rich DQN state."""

import threading

import numpy as np
import pytest

from repro.cohort import CohortConfig
from repro.fed.metrics import cluster_policy_state, serving_state_dim
from repro.launch.frontend import (CohortFrontend, TenantSpec,
                                   make_demo_frontend)
from repro.launch.serve import CohortServer

FAST_DQN = {"hidden": (32,), "eps_decay_steps": 30, "buffer_size": 512,
            "batch_size": 64}


def blob_table(n=120, k=3, d=8, sep=8.0, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)).astype(np.float32) * sep
    true = rng.integers(0, k, n)
    x = (centers[true] + rng.normal(size=(n, d)).astype(np.float32))
    return x, true


def mk_frontend(tenants=2, n=120, k=3, d=8, policy="stratified", seed=0,
                window=0.0):
    fe = make_demo_frontend(tenants, n, d,
                            config=CohortConfig(num_clusters=k),
                            seed=seed, policy=policy, batch_window_s=window)
    for i, name in enumerate(fe.tenant_names):
        x, _ = blob_table(n, k, d, seed=seed + i)
        fe.update_embeddings(name, np.arange(n), x)
    return fe


# -- coalescing -----------------------------------------------------------

def test_concurrent_selects_coalesce_to_one_solve_disjoint_cohorts():
    """16 concurrent selects on one table version: exactly one engine
    solve for that version, every request served, and the batch's
    cohorts pairwise disjoint (shared pools, popped without
    replacement)."""
    n, workers = 200, 16
    fe = mk_frontend(tenants=1, n=n, k=4, window=0.5)
    name = fe.tenant_names[0]
    server = fe.tenant(name)

    results = [None] * workers
    barrier = threading.Barrier(workers)

    def worker(i):
        barrier.wait()
        results[i] = fe.select_cohort(name, 8)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert all(r is not None for r in results)
    # one engine solve total for this table version — every other entry
    # was either coalesced into the batch or a fingerprint-cache replay
    assert server.engine.stats["solves"] == 1
    assert server.engine.stats["cold_starts"] == 1
    # the generous window + barrier coalesce the full herd into one batch
    st = fe.stats()
    assert st["frontend"]["requests"] == workers
    assert st["frontend"]["max_batch"] == workers
    assert st["frontend"]["batches"] == 1
    # disjoint cohorts: no client served twice across the batch
    all_ids = np.concatenate([ids for ids, _ in results])
    assert len(all_ids) == workers * 8
    assert len(np.unique(all_ids)) == len(all_ids)
    # every waiter sees the same solve (single CohortResult fanned out)
    versions = {id(res) for _, res in results}
    assert len(versions) == 1


def test_batched_select_counters_and_dashboard_factor():
    fe = mk_frontend(tenants=1, n=90, k=3, window=0.0)
    name = fe.tenant_names[0]
    server = fe.tenant(name)
    out = server.select_cohorts([5, 5, 5])
    assert len(out) == 3
    assert server.engine.stats["batched_selects"] == 1
    assert server.engine.stats["coalesced_requests"] == 3
    assert server.stats()["requests"] == 3
    assert server.stats()["batches"] == 1
    ids = np.concatenate([i for i, _ in out])
    assert len(np.unique(ids)) == 15
    assert server.select_cohorts([]) == []


def test_new_table_version_does_not_coalesce_with_old_batch():
    """Requests racing a table update still get a consistent solve: a
    version bump opens a new batch rather than joining the stale one."""
    fe = mk_frontend(tenants=1, n=90, k=3, window=0.0)
    name = fe.tenant_names[0]
    ids1, res1 = fe.select_cohort(name, 6)
    x, _ = blob_table(90, 3, 8, seed=99)
    fe.update_embeddings(name, np.arange(90), x)
    ids2, res2 = fe.select_cohort(name, 6)
    assert res2 is not res1
    assert fe.tenant(name).engine.stats["solves"] == 2


def test_frontend_select_error_fans_out_and_unknown_tenant():
    fe = mk_frontend(tenants=1, n=60, k=3)
    with pytest.raises(KeyError, match="unknown tenant"):
        fe.select_cohort("no-such-family", 4)
    name = fe.tenant_names[0]

    def boom(*a, **kw):
        raise RuntimeError("engine exploded")

    fe.tenant(name).select_cohorts = boom
    with pytest.raises(RuntimeError, match="coalesced select failed"):
        fe.select_cohort(name, 4)


# -- tenant isolation -----------------------------------------------------

def test_tenants_are_isolated_seeds_policies_stats():
    """Each tenant shard owns its table/engine/policy: updates and
    selects against one never move another's version, counters, or
    policy state; per-tenant seeds decorrelate the draws."""
    fe = mk_frontend(tenants=2, n=120, k=3, policy="dqn", seed=0)
    a, b = fe.tenant_names
    assert fe.tenant(a) is not fe.tenant(b)
    assert fe.tenant(a).engine is not fe.tenant(b).engine
    assert fe.tenant(a).policy is not fe.tenant(b).policy

    v_b = fe.tenant(b).version
    ids_a, _ = fe.select_cohort(a, 10)
    fe.observe_round(a, 0.7)
    st = fe.stats()["tenants"]
    assert st[a]["requests"] == 1 and st[b]["requests"] == 0
    assert st[a]["rounds_observed"] == 1 and st[b]["rounds_observed"] == 0
    assert fe.tenant(b).version == v_b
    assert st[b]["policy"]["buffer_size"] == 0
    assert st[a]["policy"]["buffer_size"] > 0

    # independent seeds: the two shards' Q-networks differ at init
    qa = fe.tenant(a).policy.agent
    qb = fe.tenant(b).policy.agent
    import jax
    leaves_a = jax.tree_util.tree_leaves(qa.params)
    leaves_b = jax.tree_util.tree_leaves(qb.params)
    assert any(not np.array_equal(np.asarray(la), np.asarray(lb))
               for la, lb in zip(leaves_a, leaves_b))


def test_duplicate_tenant_rejected():
    fe = CohortFrontend()
    fe.add_tenant("fam", TenantSpec("fam", 40, 4,
                                    config=CohortConfig(num_clusters=2)))
    with pytest.raises(ValueError, match="already registered"):
        fe.add_tenant("fam", CohortServer(40, 4))


# -- rich (5k+1) serving state --------------------------------------------

def test_rich_state_round_trip_through_observe_round():
    """The widened 5k+1 state flows select -> observe_round -> replay:
    the policy is built for 5k+1, draws and learns on it, and the
    buffer's stored transitions have the widened shape."""
    n, k, d = 120, 3, 8
    x, _ = blob_table(n, k, d)
    srv = CohortServer(n, d, seed=0, policy="dqn",
                       config=CohortConfig(num_clusters=k),
                       dqn_overrides=FAST_DQN)     # default rich
    srv.update_embeddings(np.arange(n), x)
    dim = serving_state_dim(k, "rich")
    assert dim == 5 * k + 1
    assert srv.policy.state_dim == dim
    for _ in range(3):
        ids, res = srv.select_cohort(10)
        assert len(ids) == 10
        srv.observe_round(0.6)
    assert srv.policy.agent.buffer.s.shape[1] == dim
    assert srv.policy.agent.buffer.size > 0
    st = srv.stats()
    assert st["state_features"] == "rich"
    assert st["policy"]["state_dim"] == dim
    assert st["policy"]["state_features"] == "rich"
    # dispersion features live in [0, 1) and are not all zero for a
    # real blob table; staleness starts fresh after serving
    state = srv._policy_state(res.assign, srv.embeds)
    disp = state[3 * k: 4 * k]
    stale = state[4 * k: 5 * k]
    assert np.all((disp >= 0) & (disp < 1)) and disp.max() > 0
    assert np.all((stale >= 0) & (stale < 1))


def test_basic_state_features_backcompat():
    """state_features='basic' keeps the legacy 3k+1 replay shape."""
    n, k, d = 90, 3, 8
    x, _ = blob_table(n, k, d)
    srv = CohortServer(n, d, seed=0, policy="dqn",
                       config=CohortConfig(num_clusters=k),
                       dqn_overrides=FAST_DQN, state_features="basic")
    srv.update_embeddings(np.arange(n), x)
    assert srv.policy.state_dim == 3 * k + 1
    ids, _ = srv.select_cohort(8)
    srv.observe_round(0.6)
    assert srv.policy.agent.buffer.s.shape[1] == 3 * k + 1
    with pytest.raises(ValueError, match="unknown state features"):
        CohortServer(n, d, state_features="extra")


def test_staleness_ages_unserved_clusters():
    """Clusters that stop contributing clients age in the staleness
    feature; clusters just served read fresh (0)."""
    n, k, d = 120, 3, 8
    x, _ = blob_table(n, k, d)
    srv = CohortServer(n, d, seed=0, policy="stratified",
                       config=CohortConfig(num_clusters=k))
    srv.update_embeddings(np.arange(n), x)
    ids, res = srv.select_cohort(n)          # everyone served: all fresh
    assert np.all(srv._staleness == 0.0)
    # serve only cluster 0's clients by hand-picking sizes of 0 from
    # the others: a tiny cohort will only touch some clusters
    ids, res = srv.select_cohort(1)
    served = np.unique(res.assign[ids])
    unserved = [c for c in range(k) if c not in served]
    assert np.all(srv._staleness[served] == 0.0)
    assert all(srv._staleness[c] == 1.0 for c in unserved)


def test_cluster_policy_state_validates_short_stats():
    """Per-cluster stats shorter than k must fail loudly, not emit a
    silently wrong-length state (the old [:k] slice bug)."""
    assign = np.array([0, 1, 2, 0])
    with pytest.raises(ValueError, match="participation has length 2"):
        cluster_policy_state(assign, 3, np.zeros(2), np.zeros(3), 0.5,
                             features="basic")
    with pytest.raises(ValueError, match="reward_ema has length 1"):
        cluster_policy_state(assign, 3, np.zeros(3), np.zeros(1), 0.5,
                             features="basic")
    # rich without its inputs is a clear error too
    with pytest.raises(ValueError, match="embeds"):
        cluster_policy_state(assign, 3, np.zeros(3), np.zeros(3), 0.5)
    # longer arrays (historical k̂ > k) still slice cleanly
    s = cluster_policy_state(assign, 3, np.zeros(5), np.zeros(5), 0.5,
                             features="basic")
    assert s.shape == (3 * 3 + 1,)


def test_cluster_policy_wrong_length_state_clear_error():
    from repro.policy import ClusterPolicy
    pol = ClusterPolicy(3, state_dim=16, seed=0, dqn_overrides=FAST_DQN,
                        state_features="rich")
    with pytest.raises(ValueError, match="state_dim=16"):
        pol.draw_weights(np.zeros(10, np.float32))
    with pytest.raises(ValueError, match="ClusterPolicy.observe"):
        pol.observe(np.zeros(16, np.float32), [0], 1.0,
                    np.zeros(9, np.float32))


def test_watchdog_instrumented_stack_obeys_declared_lock_order():
    """Satellite of the repro-lint lock rules: run the serving stack
    with every lock swapped for a rank-asserting
    :class:`repro.analysis.OrderedLock` and hammer it from selector /
    updater / observer / stats threads.  This covers the one edge the
    static analyzer cannot see — ``select_cohorts`` (holding
    ``_select_lock``) calling back into the frontend's ``seal`` closure,
    which takes the tenant lock — and turns any future inversion into a
    deterministic :class:`LockOrderError` instead of a rare deadlock.
    """
    from repro.analysis import instrument

    fe = mk_frontend(tenants=2, n=120, k=3, policy="dqn", window=0.0)
    assert instrument(fe) == ["_registry_lock"]
    for name in fe.tenant_names:
        tenant = fe._tenants[name]
        assert instrument(tenant, prefix=f"{name}:") == ["lock"]
        assert sorted(instrument(tenant.server, prefix=f"{name}:")) == [
            "_publish_lock", "_select_lock", "_solve_lock",
            "_stats_lock", "_write_lock"]

    errors, done = [], []
    rng = np.random.default_rng(1)

    def hammer(i):
        name = fe.tenant_names[i % len(fe.tenant_names)]
        server = fe.tenant(name)
        try:
            for r in range(4):
                ids, _ = fe.select_cohort(name, 6)
                server.observe_round(0.5 + 0.01 * len(ids),
                                     timings={"train": 0.01})
                server.update_embeddings(
                    ids, rng.normal(size=(len(ids), 8)).astype(np.float32))
                fe.stats()
            done.append(i)
        except Exception as exc:        # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert errors == []
    assert len(done) == 8
