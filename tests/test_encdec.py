import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import encdec as E

KEY = jax.random.PRNGKey(0)
CFG = get_config("seamless-m4t-medium").reduced()
B, S = 2, 12


def _batch():
    return {"src_embeds": jax.random.normal(
                KEY, (B, CFG.encoder_seq_len, CFG.d_model), jnp.float32),
            "tokens": jax.random.randint(KEY, (B, S), 0, CFG.vocab_size),
            "labels": jax.random.randint(
                jax.random.fold_in(KEY, 1), (B, S), 0, CFG.vocab_size)}


def test_encoder_is_bidirectional():
    """Flipping a late source frame must change EARLY encoder outputs."""
    params = E.init_encdec(KEY, CFG)
    batch = _batch()
    m1 = E.encode(params, CFG, batch["src_embeds"])
    src2 = batch["src_embeds"].at[:, -1].add(3.0)
    m2 = E.encode(params, CFG, src2)
    assert not np.allclose(np.asarray(m1[:, 0]), np.asarray(m2[:, 0]),
                           atol=1e-5)


def test_decoder_is_causal():
    """Changing a late target token must NOT change earlier decode logits."""
    params = E.init_encdec(KEY, CFG)
    batch = _batch()
    memory = E.encode(params, CFG, batch["src_embeds"])
    import repro.models.layers as L
    h1 = L.embed(params["embed"], batch["tokens"]).astype(jnp.float32)
    out1, _ = E._decoder(params, CFG, h1, memory,
                         positions=jnp.arange(S))
    toks2 = batch["tokens"].at[:, -1].set(0)
    h2 = L.embed(params["embed"], toks2).astype(jnp.float32)
    out2, _ = E._decoder(params, CFG, h2, memory,
                         positions=jnp.arange(S))
    np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                               np.asarray(out2[:, :-1]), atol=1e-5)


def test_decode_step_matches_teacher_forcing():
    params = E.init_encdec(KEY, CFG)
    batch = _batch()
    memory = E.encode(params, CFG, batch["src_embeds"])
    import repro.models.layers as L
    h = L.embed(params["embed"], batch["tokens"]).astype(jnp.float32)
    full, _ = E._decoder(params, CFG, h, memory, positions=jnp.arange(S))
    from repro.models.transformer import lm_logits
    full_logits = lm_logits(params, CFG, full)

    caches = E.init_encdec_cache(CFG, B, S)
    _, caches = E.encdec_prefill(params, CFG,
                                 dict(batch, tokens=batch["tokens"][:, :-1]),
                                 caches)
    step_logits, _ = E.encdec_decode_step(params, CFG,
                                          batch["tokens"][:, -1:], caches,
                                          jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, -1]), atol=2e-3)


@pytest.mark.slow
def test_train_loss_finite_and_decreases():
    params = E.init_encdec(KEY, CFG)
    batch = _batch()
    loss, _ = E.encdec_train_loss(params, CFG, batch, remat=False)
    assert np.isfinite(float(loss))
    grads = jax.grad(
        lambda p: E.encdec_train_loss(p, CFG, batch, remat=False)[0])(params)
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    loss2, _ = E.encdec_train_loss(params2, CFG, batch, remat=False)
    assert float(loss2) < float(loss)
