import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer, load_pytree, save_pytree


def _tree():
    return {"params": {"layer": [jnp.arange(4.0), jnp.ones((2, 3))],
                       "scale": jnp.float32(2.0)},
            "step": jnp.int32(7),
            "nested": {"t": (jnp.zeros(2), jnp.ones(1))},
            "maybe": None}


def test_roundtrip_preserves_structure_and_values(tmp_path):
    tree = _tree()
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, tree)
    back = load_pytree(path, template=tree)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpointer_retention_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 5, 9):
        ck.save(step, {"w": jnp.full((2,), float(step))},
                {"note": f"s{step}"})
    assert ck.steps() == [5, 9]                     # keep=2 retention
    tree, step, meta = ck.restore(template={"w": jnp.zeros(2)})
    assert step == 9 and meta["note"] == "s9"
    np.testing.assert_allclose(np.asarray(tree["w"]), 9.0)


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, {"w": jnp.ones(1)})
    ck.save(2, {"w": jnp.ones(1) * 2})
    tree, step, _ = ck.restore(step=1)
    assert step == 1
    np.testing.assert_allclose(np.asarray(tree["w"]), 1.0)
