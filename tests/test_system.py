"""End-to-end behaviour tests for the system as a whole."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, SHAPES
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def test_chunked_ce_equals_direct_ce():
    cfg = get_config("qwen2-7b").reduced()
    params = T.init_lm(KEY, cfg)
    B, S = 2, 20
    h = jax.random.normal(KEY, (B, S, cfg.d_model))
    labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    chunked = T.chunked_ce_loss(params, cfg, h, labels, chunk=8)
    logits = T.lm_logits(params, cfg, h)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    direct = jnp.mean(lse - gold)
    np.testing.assert_allclose(float(chunked), float(direct), rtol=1e-5)


def test_training_reduces_loss_small_lm():
    """A tiny LM must actually learn the synthetic Markov stream."""
    from repro.data import TokenDataConfig, synthetic_token_batches
    from repro.launch.steps import make_optimizer, make_train_step
    from repro.configs.base import ShapeConfig

    from repro.optim import adam

    cfg = dataclasses.replace(get_config("gemma-2b").reduced(),
                              vocab_size=64, num_layers=2)
    shape = ShapeConfig("t", 32, 8, "train")
    # constant LR: the production schedule warms up over 200 steps, far
    # longer than this 30-step smoke run
    opt = adam(3e-3)
    step_fn = jax.jit(make_train_step(cfg, shape, opt))
    params = T.init_lm(KEY, cfg)
    opt_state = opt.init(params)
    data = TokenDataConfig(vocab_size=64, seq_len=32, global_batch=8, seed=0)
    losses = []
    for i, batch in enumerate(synthetic_token_batches(data, 30)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, jnp.int32(i), batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_greedy_decode_continues_prefill():
    """Serving path: incremental decode must reproduce step-by-step full
    recompute (system-level consistency across prefill/decode/caches)."""
    cfg = get_config("qwen2-7b").reduced()
    params = T.init_lm(KEY, cfg)
    B, S, gen = 1, 8, 4
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    caches = T.init_lm_cache(cfg, B, S + gen)
    logits, caches = T.lm_prefill(params, cfg, {"tokens": toks}, caches)
    out_inc = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for s in range(gen):
        out_inc.append(int(tok[0, 0]))
        logits, caches = T.lm_decode_step(params, cfg, tok, caches,
                                          jnp.int32(S + s))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    # oracle: recompute the full forward at each step
    cur = toks
    out_full = []
    for s in range(gen):
        h = T.embed_inputs(params, cfg, cur)
        hh, _, _ = T.lm_hidden(params, cfg, h,
                               positions=jnp.arange(cur.shape[1]))
        lg = T.lm_logits(params, cfg, hh[:, -1:])[:, 0]
        nxt = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        out_full.append(int(nxt[0, 0]))
        cur = jnp.concatenate([cur, nxt], axis=1)
    assert out_inc == out_full


def test_all_shapes_defined():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["long_500k"].global_batch == 1
    assert SHAPES["train_4k"].global_batch == 256


def test_registry_covers_all_ten_archs():
    assert len(list_archs()) == 10


def test_fl_single_round_end_to_end():
    from repro.fed import FederatedRunner, RunnerConfig
    cfg = RunnerConfig(dataset="fashion_mnist", num_clients=8,
                       clients_per_round=3, sigma=0.5, local_steps=3,
                       batch_size=8, train_size=400, eval_size=128,
                       policy="kcenter", seed=1)
    runner = FederatedRunner(cfg)
    res = runner.run_round()
    assert 0.0 <= res.accuracy <= 1.0
    assert len(res.selected) == 3
