import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels import ref
from repro.models import attention as A

KEY = jax.random.PRNGKey(0)


def mk_cfg(**kw):
    base = dict(name="t", arch_type="dense", num_layers=2, d_model=32,
                num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                vocab_size=128, param_dtype="float32",
                compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_gqa_matches_ref():
    cfg = mk_cfg()
    p = A.attn_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, 32))
    out, _ = A.attention(p, x, cfg, positions=jnp.arange(16))
    assert out.shape == (2, 16, 32)
    assert not np.isnan(np.asarray(out)).any()


@pytest.mark.parametrize("causal,window", [(True, None), (True, 8),
                                           (False, None)])
def test_blocked_attention_matches_naive(causal, window):
    B, S, H, K, d = 2, 50, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, H, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, K, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, K, d))
    blocked = A.blocked_attention(q, k, v, causal=causal, window=window,
                                  q_chunk=16, kv_chunk=8)
    naive = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(blocked, naive, atol=2e-5)


def test_qk_norm_and_bias_paths():
    cfg = mk_cfg(qk_norm=True, qkv_bias=True)
    p = A.attn_init(KEY, cfg)
    assert "q_norm" in p and "b" in p["wq"]
    out, _ = A.attention(p, jax.random.normal(KEY, (1, 8, 32)), cfg,
                         positions=jnp.arange(8))
    assert not np.isnan(np.asarray(out)).any()


def test_mqa_kv1():
    cfg = mk_cfg(num_heads=4, num_kv_heads=1)
    p = A.attn_init(KEY, cfg)
    out, _ = A.attention(p, jax.random.normal(KEY, (1, 8, 32)), cfg,
                         positions=jnp.arange(8))
    assert out.shape == (1, 8, 32)


def test_decode_cache_matches_full_forward():
    cfg = mk_cfg()
    p = A.attn_init(KEY, cfg)
    S = 12
    x = jax.random.normal(KEY, (2, S, 32))
    full, _ = A.attention(p, x, cfg, positions=jnp.arange(S))
    cache = A.init_kv_cache(cfg, 2, S, jnp.float32)
    # prefill S-1, then one decode step
    _, cache = A.attention(p, x[:, :S - 1], cfg,
                           positions=jnp.arange(S - 1), cache=cache,
                           cache_pos=0)
    step, _ = A.attention(p, x[:, S - 1:], cfg,
                          positions=jnp.arange(S - 1, S), cache=cache,
                          cache_pos=S - 1)
    np.testing.assert_allclose(step[:, 0], full[:, -1], atol=1e-4)


def test_sliding_window_restricts_context():
    cfg = mk_cfg()
    p = A.attn_init(KEY, cfg)
    S = 32
    x = jax.random.normal(KEY, (1, S, 32))
    full, _ = A.attention(p, x, cfg, positions=jnp.arange(S))
    win, _ = A.attention(p, x, cfg, positions=jnp.arange(S), window=4)
    # early positions (inside window) agree; late positions differ
    np.testing.assert_allclose(win[:, :4], full[:, :4], atol=1e-4)
    assert not np.allclose(win[:, -1], full[:, -1], atol=1e-3)
