"""Federated runtime: partitioning, aggregation, end-to-end rounds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed import (FederatedRunner, RunnerConfig, fedavg_aggregate,
                       make_dataset, partition_non_iid, sigma_to_alpha)
from repro.fed.partition import label_histogram

KEY = jax.random.PRNGKey(0)


def test_sigma_alpha_monotone():
    alphas = [sigma_to_alpha(s) for s in (0.0, 0.3, 0.5, 0.8, 1.0)]
    assert all(a > b for a, b in zip(alphas, alphas[1:]))


def test_partition_covers_all_clients_with_minimum():
    y = np.random.default_rng(0).integers(0, 10, 2000).astype(np.int32)
    shards = partition_non_iid(y, 50, 0.8, seed=0)
    assert len(shards) == 50
    assert min(len(s) for s in shards) >= 8


def test_higher_sigma_more_skew():
    y = np.random.default_rng(0).integers(0, 10, 8000).astype(np.int32)

    def skew(sigma):
        shards = partition_non_iid(y, 20, sigma, seed=0)
        hist = label_histogram(y, shards, 10)
        hist = hist / np.maximum(hist.sum(axis=1, keepdims=True), 1e-12)
        # mean per-client entropy: lower = more skew.  Mask BEFORE the log:
        # np.log evaluates eagerly on the zero bins and np.where only picks
        # afterwards, so the unmasked form emits divide/invalid warnings.
        log_hist = np.log(hist, out=np.zeros_like(hist), where=hist > 0)
        ent = -np.sum(hist * log_hist, axis=1)
        return ent.mean()

    assert skew(0.0) > skew(0.8) > skew(1.0) - 1e-9


def test_macro_auc_uses_midranks_under_ties():
    """Tied logits must contribute 1/2 per tied (pos, neg) pair.  The
    old double-argsort assigned ties ordinal ranks by memory order, so
    the AUC depended on which class happened to come first."""
    from repro.fed.metrics import classification_metrics

    # binary, class-0 column: pos scores [1, 1], neg scores [1, 0]
    # exact AUC = mean over pairs of 1[pos>neg] + 0.5*1[pos==neg]
    #           = (0.5 + 1 + 0.5 + 1) / 4 = 0.75 for class 0
    y = np.array([0, 0, 1, 1])
    logits = np.array([[1.0, 0.0],
                       [1.0, 0.0],
                       [1.0, 1.0],      # ties class-0 score with the pos
                       [0.0, 1.0]])
    m = classification_metrics(y, logits)
    # class 1 column: pos [1, 1] vs neg [0, 0] -> AUC 1; macro = 0.875
    assert m["auc"] == pytest.approx((0.75 + 1.0) / 2)

    # order invariance: relabeling row order must not change the AUC
    perm = np.array([3, 1, 0, 2])
    m2 = classification_metrics(y[perm], logits[perm])
    assert m2["auc"] == pytest.approx(m["auc"])

    # all-tied logits carry no ranking information: AUC is exactly 1/2
    m3 = classification_metrics(y, np.ones((4, 2)))
    assert m3["auc"] == pytest.approx(0.5)


def test_macro_auc_matches_ordinal_ranks_without_ties():
    """With distinct scores midranks equal ordinal ranks — the fix only
    changes tied inputs."""
    from repro.fed.metrics import classification_metrics

    rng = np.random.default_rng(0)
    y = rng.integers(0, 3, 60)
    logits = rng.normal(size=(60, 3))          # ties have measure zero
    m = classification_metrics(y, logits)
    aucs = []
    for c in range(3):
        pos, neg = logits[y == c, c], logits[y != c, c]
        ranks = np.argsort(np.argsort(np.concatenate([pos, neg])))
        aucs.append((ranks[: len(pos)].sum() - len(pos) * (len(pos) - 1) / 2)
                    / (len(pos) * len(neg)))
    assert m["auc"] == pytest.approx(float(np.mean(aucs)))


def test_fedavg_aggregate_weighted_mean():
    p1 = {"w": jnp.ones((2, 2))}
    stacked = {"w": jnp.stack([jnp.ones((2, 2)), 3 * jnp.ones((2, 2))])}
    out = fedavg_aggregate(stacked, jnp.asarray([1.0, 3.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5)


def test_dataset_shapes_and_determinism():
    d1 = make_dataset("cifar10", seed=3, train_size=64, test_size=32)
    d2 = make_dataset("cifar10", seed=3, train_size=64, test_size=32)
    assert d1["x_train"].shape == (64, 32, 32, 3)
    np.testing.assert_array_equal(d1["x_train"], d2["x_train"])
    assert set(np.unique(d1["y_train"])) <= set(range(10))


@pytest.mark.slow
def test_integration_rounds_improve_accuracy():
    cfg = RunnerConfig(dataset="mnist", num_clients=10, clients_per_round=4,
                       sigma=0.5, local_steps=8, batch_size=16,
                       train_size=1200, eval_size=256, policy="fedavg",
                       seed=0)
    runner = FederatedRunner(cfg)
    hist = runner.run(8)
    assert hist[-1].accuracy > hist[0].accuracy + 0.2
    assert hist[-1].accuracy > 0.5
    # per-phase perf_counter timings are recorded and sum to the round
    for res in hist:
        assert {"select", "train", "aggregate", "evaluate",
                "update"} <= set(res.timings)
        assert all(t >= 0 for t in res.timings.values())
        assert abs(sum(res.timings.values()) - res.seconds) < 1e-3


@pytest.mark.slow
def test_integration_dqre_sc_runs_and_learns():
    cfg = RunnerConfig(dataset="mnist", num_clients=12, clients_per_round=4,
                       sigma=0.8, local_steps=8, batch_size=16,
                       train_size=1200, eval_size=256, policy="dqre_sc",
                       num_clusters=3, embed_dim=4, seed=0)
    runner = FederatedRunner(cfg)
    hist = runner.run(8)
    assert hist[-1].accuracy > 0.4
    m = runner.final_metrics()
    assert 0.0 <= m["auc"] <= 1.0 and m["accuracy"] > 0.3
