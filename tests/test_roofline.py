"""Roofline calculator + HLO collective parser unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, SHAPES
from repro.roofline.analysis import (HW, collective_bytes_from_hlo,
                                     _shape_bytes)
from repro.roofline.calculator import (MeshShape, cache_bytes,
                                       forward_flops, roofline_terms,
                                       step_collective_bytes, step_flops)


MESH = MeshShape(dp=16, tp=16)


def test_shape_bytes_parsing():
    assert _shape_bytes("f32[4,8]") == 4 * 8 * 4
    assert _shape_bytes("bf16[2,3,5]") == 30 * 2
    assert _shape_bytes("(f32[4], bf16[8])") == 16 + 16
    assert _shape_bytes("pred[16]") == 16


def test_collective_parser_counts_real_hlo():
    """Parse collectives out of an actual lowered module."""
    import os
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((4,), ("d",))
        s = NamedSharding(mesh, P("d"))
        f = jax.jit(lambda x: jnp.sum(x), in_shardings=s,
                    out_shardings=NamedSharding(mesh, P()))
        print(f.lower(jax.ShapeDtypeStruct((16, 4), jnp.float32))
              .compile().as_text())
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-500:]
    coll = collective_bytes_from_hlo(r.stdout)
    assert coll["all-reduce"] > 0          # sum over sharded dim
    assert coll["total_bytes"] >= coll["all-reduce"]


def test_forward_flops_scales_with_tokens():
    cfg = get_config("qwen2-7b")
    f_train = forward_flops(cfg, SHAPES["train_4k"])["total"]
    f_prefill = forward_flops(cfg, SHAPES["prefill_32k"])["total"]
    # same token count (1M), prefill has longer context -> more attn flops
    assert f_prefill > f_train
    f_decode = forward_flops(cfg, SHAPES["decode_32k"])["total"]
    assert f_decode < f_train / 100        # 1 token vs 4096


def test_train_multiplier_covers_fwd_bwd_remat():
    cfg = get_config("gemma-2b")
    fwd = forward_flops(cfg, SHAPES["train_4k"])["total"]
    tot = step_flops(cfg, SHAPES["train_4k"])["total"]
    assert 3.0 * fwd < tot < 4.5 * fwd


def test_useful_ratio_below_one_everywhere():
    for arch in ("qwen3-14b", "deepseek-v3-671b", "mamba2-2.7b",
                 "jamba-v0.1-52b"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            class _M:                       # minimal mesh stand-in
                axis_names = ("data", "model")
                shape = {"data": 16, "model": 16}
                size = 256
            r = roofline_terms(cfg, shape, MeshShape(16, 16), 8)
            assert r["useful_flop_ratio"] is not None
            assert r["useful_flop_ratio"] <= 1.0 + 1e-6, (arch, shape.name)


def test_mla_cache_smaller_than_gqa_equivalent():
    ds = get_config("deepseek-v3-671b")
    qw = get_config("qwen3-14b")
    ds_per_layer_tok = cache_bytes(ds, SHAPES["decode_32k"]) / ds.num_layers
    qw_per_layer_tok = cache_bytes(qw, SHAPES["decode_32k"]) / qw.num_layers
    # MLA latent (576) vs GQA 2*8*128 = 2048 dims per token
    assert ds_per_layer_tok < qw_per_layer_tok


def test_ep_layout_removes_expert_fsdp_traffic():
    """H2/H11: routed-expert bytes must NOT appear in fsdp_allgather."""
    ds = get_config("deepseek-v3-671b")
    co = step_collective_bytes(ds, SHAPES["train_4k"], MESH, 16)
    expert_bytes = ds.routed_expert_param_count() * 2
    # if experts were in the gather, the term would exceed this bound
    assert co["fsdp_allgather"] < 3 * 16 * expert_bytes * 0.1
    assert co["moe_all_to_all"] > 0


def test_windowed_decode_reduces_executed_flops():
    cfg = get_config("qwen3-14b")
    full = forward_flops(cfg, SHAPES["decode_32k"])["total"]
    win = forward_flops(cfg, SHAPES["long_500k"])["total"]
    # 500k cache but 8k window => attention work comparable to 32k decode
    # at 1/128 the batch
    assert win < full


def test_hw_constants_match_assignment():
    assert HW.peak_flops == 197e12
    assert HW.hbm_bw == 819e9
    assert HW.ici_bw == 50e9
