"""MLA: absorbed decode path must match the expanded path exactly."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MLAConfig, ModelConfig
from repro.models import mla as MLA

KEY = jax.random.PRNGKey(0)


def mk_cfg():
    return ModelConfig(
        name="t", arch_type="moe", num_layers=1, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=64, use_mla=True,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        param_dtype="float32", compute_dtype="float32")


def test_expanded_forward_shapes():
    cfg = mk_cfg()
    p = MLA.mla_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 12, 64))
    out, cache = MLA.mla_attention(p, x, cfg, positions=jnp.arange(12))
    assert out.shape == (2, 12, 64)
    assert cache is None


def test_absorbed_decode_matches_expanded():
    """The low-rank-absorbed decode must reproduce the expanded attention
    output at the last position (the correctness core of MLA serving)."""
    cfg = mk_cfg()
    p = MLA.mla_init(KEY, cfg)
    S = 9
    x = jax.random.normal(KEY, (2, S, 64))
    full, _ = MLA.mla_attention(p, x, cfg, positions=jnp.arange(S))

    cache = MLA.init_mla_cache(cfg, 2, S, jnp.float32)
    _, cache = MLA.mla_attention(p, x[:, : S - 1], cfg,
                                 positions=jnp.arange(S - 1), cache=cache,
                                 cache_pos=0)
    step, _ = MLA.mla_attention(p, x[:, S - 1:], cfg,
                                positions=jnp.arange(S - 1, S),
                                cache=cache, cache_pos=S - 1)
    np.testing.assert_allclose(np.asarray(step[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-4)


def test_cache_is_compressed():
    """MLA's point: cached bytes per token = kv_lora + rope_dim, far below
    2 * H * head_dim of standard GQA."""
    cfg = mk_cfg()
    cache = MLA.init_mla_cache(cfg, 1, 128, jnp.float32)
    per_token = sum(np.prod(v.shape[2:]) for v in cache.values())
    gqa_per_token = 2 * cfg.num_heads * (cfg.mla.qk_nope_head_dim
                                         + cfg.mla.qk_rope_head_dim)
    assert per_token < gqa_per_token / 3


def test_window_masks_decode():
    cfg = mk_cfg()
    p = MLA.mla_init(KEY, cfg)
    S = 12
    x = jax.random.normal(KEY, (1, S, 64))
    cache = MLA.init_mla_cache(cfg, 1, S, jnp.float32)
    _, cache = MLA.mla_attention(p, x[:, :-1], cfg,
                                 positions=jnp.arange(S - 1), cache=cache,
                                 cache_pos=0)
    full_step, _ = MLA.mla_attention(p, x[:, -1:], cfg,
                                     positions=jnp.arange(S - 1, S),
                                     cache=cache, cache_pos=S - 1)
    win_step, _ = MLA.mla_attention(p, x[:, -1:], cfg,
                                    positions=jnp.arange(S - 1, S),
                                    cache=cache, cache_pos=S - 1, window=3)
    assert not np.allclose(np.asarray(full_step), np.asarray(win_step),
                           atol=1e-4)
