"""Launch layer: server loop, batch specs, cache pspec rules,
microbatch clamping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, SHAPES
from repro.configs.base import ShapeConfig
from repro.launch.serve import Request, Server
from repro.launch.steps import (batch_specs, cache_pspecs, cache_specs,
                                decode_window, num_microbatches)


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}
    size = 256


def test_batch_specs_shapes():
    cfg = get_config("qwen3-14b")
    b = batch_specs(cfg, SHAPES["train_4k"])
    assert b["tokens"].shape == (256, 4096)
    vlm = get_config("internvl2-26b")
    b = batch_specs(vlm, SHAPES["train_4k"])
    assert b["tokens"].shape == (256, 4096 - 256)
    assert b["prefix_embeds"].shape == (256, 256, 6144)
    enc = get_config("seamless-m4t-medium")
    b = batch_specs(enc, SHAPES["prefill_32k"])
    assert b["src_embeds"].shape == (32, 32768, 1024)


def test_cache_pspecs_rules():
    cfg = get_config("qwen3-14b")
    cache = cache_specs(cfg, 128, 32768)          # decode_32k
    specs = cache_pspecs(cache, FakeMesh(), 128)
    leaf_spec = specs[0]["blocks"][0]["k"]
    # batch over (data), seq over model (flash-decode layout)
    assert leaf_spec == P(None, ("data",), "model", None, None)

    cache1 = cache_specs(cfg, 1, 524288)          # long_500k
    specs1 = cache_pspecs(cache1, FakeMesh(), 1)
    leaf1 = specs1[0]["blocks"][0]["k"]
    assert leaf1 == P(None, None, ("data", "model"), None, None)


def test_cache_pspecs_ssm_heads_on_model():
    cfg = get_config("mamba2-2.7b")
    cache = cache_specs(cfg, 128, 32768)
    specs = cache_pspecs(cache, FakeMesh(), 128)
    ssm_spec = specs[0]["blocks"][0]["ssm"]
    assert ssm_spec == P(None, ("data",), "model", None, None)


def test_num_microbatches_respects_dp():
    cfg = get_config("deepseek-v3-671b")
    shape = SHAPES["train_4k"]
    g16 = num_microbatches(cfg, shape, dp=16)
    g32 = num_microbatches(cfg, shape, dp=32)
    assert shape.global_batch % (g16 * 16) == 0
    assert shape.global_batch % (g32 * 32) == 0
    assert g32 <= g16


def test_decode_window_policy():
    assert decode_window(get_config("qwen3-14b"), SHAPES["long_500k"]) \
        == 8192
    assert decode_window(get_config("qwen3-14b"), SHAPES["decode_32k"]) \
        is None
    # SSM/hybrid handle long context natively — no window
    assert decode_window(get_config("mamba2-2.7b"), SHAPES["long_500k"]) \
        is None
    assert decode_window(get_config("jamba-v0.1-52b"), SHAPES["long_500k"]) \
        is None


def test_server_greedy_deterministic():
    cfg = get_config("qwen2-7b").reduced()
    server = Server(cfg, batch=2, max_seq=24, temperature=0.0, seed=0)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(2)]
    reqs1 = server.serve_batch([Request(i, p, 4)
                                for i, p in enumerate(prompts)])
    reqs2 = server.serve_batch([Request(i, p, 4)
                                for i, p in enumerate(prompts)])
    assert [r.generated for r in reqs1] == [r.generated for r in reqs2]
    assert all(len(r.generated) == 4 for r in reqs1)


def test_server_pads_partial_batches():
    cfg = get_config("qwen2-7b").reduced()
    server = Server(cfg, batch=4, max_seq=16, seed=0)
    rng = np.random.default_rng(0)
    reqs = server.serve_batch(
        [Request(7, rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 3)])
    assert len(reqs) == 1 and reqs[0].uid == 7
    assert len(reqs[0].generated) == 3


def test_server_empty_batch_returns_empty():
    """An empty request list is a no-op, not an IndexError on the pad
    path (requests[0] of nothing)."""
    cfg = get_config("qwen2-7b").reduced()
    server = Server(cfg, batch=2, max_seq=16, seed=0)
    assert server.serve_batch([]) == []


def test_server_heterogeneous_prompts_sample_at_own_length():
    """A shorter prompt's first token comes from ITS last-token logits,
    not the padded batch end (which conditions on the pad zeros): the
    first generated token must match serving the same prompt alone,
    where no padding exists at all."""
    cfg = get_config("qwen2-7b").reduced()
    rng = np.random.default_rng(0)
    short = rng.integers(1, cfg.vocab_size, 5).astype(np.int32)
    long_ = rng.integers(1, cfg.vocab_size, 12).astype(np.int32)

    alone = Server(cfg, batch=1, max_seq=24, seed=0).serve_batch(
        [Request(0, short, 1)])[0].generated

    mixed = Server(cfg, batch=2, max_seq=24, seed=0).serve_batch(
        [Request(0, short, 1), Request(1, long_, 1)])
    by_uid = {r.uid: r.generated for r in mixed}
    assert by_uid[0][0] == alone[0]
    assert len(by_uid[1]) == 1
