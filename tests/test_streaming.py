"""Streaming re-cluster subsystem: swap protocol, admission, dedupe.

Pins down the tentpole invariants of ``repro.streaming`` +
``CohortServer``'s double-buffer:

* selects never observe a torn (version, table, result) triple while a
  background solve is in flight — every engine entry sees one whole
  table, and the served version never moves backwards;
* after warm-up, selects are answered from the warmed result without an
  inline solve (and ``max_stale_versions`` forces one deterministically
  when the served version falls behind);
* admission sheds deterministically at the configured queue depth and
  token-bucket rate;
* identical-fingerprint tenants ride exactly one engine solve;
* ``CohortFrontend.close()`` drains, joins, and turns new selects into
  a typed error;
* delta-ingest buffers O(delta) updates and materializes once per
  snapshot.
"""

import threading
import time

import numpy as np
import pytest

from repro.cohort import CohortConfig
from repro.launch.frontend import CohortFrontend, TenantSpec
from repro.launch.serve import CohortServer
from repro.streaming import (AdmissionController, BackgroundSolver,
                             QueueFullError, RateLimitError,
                             ServiceClosedError, ShedError, SolveDeduper,
                             StreamingSpec)

CFG = CohortConfig(num_clusters=3)


def wait_until(predicate, timeout=20.0, step=0.005):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(step)
    return predicate()


def mk_server(n=96, d=8, *, streaming=StreamingSpec(), solver=None,
              deduper=None, seed=0, policy="stratified"):
    srv = CohortServer(n, d, seed=seed, policy=policy, config=CFG,
                       streaming=streaming, solver=solver, deduper=deduper)
    rng = np.random.default_rng(seed)
    srv.update_embeddings(np.arange(n),
                          rng.normal(size=(n, d)).astype(np.float32))
    return srv


class DummySolver:
    """submit() records but never runs — the mailbox stays empty."""

    def __init__(self):
        self.submitted = []
        self.stats = {"submitted": 0, "runs": 0, "errors": 0,
                      "coalesced": 0}

    def submit(self, key, fn):
        self.submitted.append((key, fn))
        self.stats["submitted"] += 1
        return True


# -- delta-ingest (satellite) ---------------------------------------------

def test_delta_ingest_coalesces_updates_and_materializes_on_snapshot():
    n, d = 100, 4
    srv = CohortServer(n, d, seed=0, config=CFG)
    ref = np.zeros((n, d), np.float32)
    rng = np.random.default_rng(0)
    v0, before = srv.snapshot()
    for i in range(5):
        ids = rng.integers(0, n, 7)
        rows = rng.normal(size=(7, d)).astype(np.float32)
        srv.update_embeddings(ids, rows)
        ref[ids] = rows                    # arrival order: later writes win
    # five O(delta) updates, zero O(N*d) copies so far
    assert srv.version == v0 + 5
    assert srv._materializations == 0
    version, table = srv.snapshot()
    assert version == v0 + 5
    assert srv._materializations == 1
    np.testing.assert_array_equal(table, ref)
    assert not table.flags.writeable
    # copy-on-write: the pre-update snapshot is untouched
    np.testing.assert_array_equal(before, np.zeros((n, d), np.float32))
    # idle re-snapshot: same frozen array, no new materialization
    assert srv.snapshot()[1] is table
    assert srv._materializations == 1


def test_delta_ingest_validates_ids_and_shapes_eagerly():
    srv = CohortServer(10, 4, seed=0, config=CFG)
    with pytest.raises(IndexError):
        srv.update_embeddings([10], np.zeros((1, 4), np.float32))
    with pytest.raises(ValueError):
        srv.update_embeddings([0], np.zeros((1, 3), np.float32))
    assert srv.version == 0                # failed updates don't bump


def test_delta_ingest_flushes_inline_once_pending_rivals_table():
    n = 16
    srv = CohortServer(n, 4, seed=0, config=CFG)
    rows = np.ones((n, 4), np.float32)
    srv.update_embeddings(np.arange(n), rows)   # pending == n: flush now
    assert srv._materializations == 1


# -- double-buffer swap protocol (tentpole) --------------------------------

def test_background_warm_lands_and_selects_stop_solving_inline():
    srv = mk_server()
    try:
        assert wait_until(lambda: srv.stats()["warm_ahead"] >= 1)
        inline0 = srv.stats()["forced_inline"]
        for _ in range(5):
            ids, res = srv.select_cohort(8)
            assert len(ids) == 8
        st = srv.stats()
        assert st["forced_inline"] == inline0      # zero inline solves
        assert st["served_warm"] == 5
        assert st["streaming"]["served_version"] == srv.version
    finally:
        srv.close()


def test_no_torn_tables_and_served_version_monotonic_under_churn():
    """Churn + concurrent selects: every engine entry (inline select or
    background prepare) must see one internally consistent table — all
    rows from the same update generation — and the version a select is
    served from must never move backwards.  This is the swap-protocol
    torn-read test: a mailbox swap that published a result against a
    different generation's table, or a half-applied delta flush, fails
    it deterministically.
    """
    n, d = 64, 4
    srv = CohortServer(n, d, seed=0, config=CFG, streaming=StreamingSpec())
    violations, markers = [], {}
    spy_lock = threading.Lock()

    def checked(table):
        flat = np.asarray(table)
        if not np.all(flat == flat.flat[0]):
            violations.append("torn table")
        return float(flat.flat[0])

    orig_prepare = srv.engine.prepare
    orig_batched = srv.engine.select_batched

    def spy_prepare(table):
        marker = checked(table)
        prep = orig_prepare(table)
        if prep is not None:
            with spy_lock:
                # keep the result referenced so id() can never be reused
                markers[id(prep.result)] = (prep.result, marker)
        return prep

    def spy_batched(table, requests=1):
        marker = checked(table)
        res = orig_batched(table, requests=requests)
        with spy_lock:
            markers[id(res)] = (res, marker)
        return res

    srv.engine.prepare = spy_prepare
    srv.engine.select_batched = spy_batched
    base = np.zeros((n, d), np.float32)
    srv.update_embeddings(np.arange(n), base)

    stop = threading.Event()

    def churn():
        v = 0
        while not stop.is_set():
            v += 1
            srv.update_embeddings(np.arange(n), base + np.float32(v))

    writer = threading.Thread(target=churn)
    writer.start()
    try:
        seen = []
        for _ in range(60):
            _, res = srv.select_cohort(6)
            with spy_lock:
                seen.append(markers[id(res)][1])
        assert violations == []
        # the served generation never moves backwards across selects
        assert all(a <= b for a, b in zip(seen, seen[1:]))
    finally:
        stop.set()
        writer.join(timeout=30)
        srv.close()
    assert srv.stats()["warm_ahead"] >= 1


def test_max_stale_versions_bounds_staleness_deterministically():
    n, d = 48, 4
    solver = DummySolver()                 # nothing ever warms
    srv = CohortServer(n, d, seed=0, config=CFG, solver=solver,
                       streaming=StreamingSpec(max_stale_versions=1))
    srv.update_embeddings(np.arange(n),
                          np.ones((n, d), np.float32))
    srv.select_cohort(4)                   # nothing warmed: inline (v1)
    assert srv.stats()["forced_inline"] == 1
    srv.select_cohort(4)                   # served v1 == table v1: warm
    srv.update_embeddings([0], np.zeros((1, d), np.float32))
    srv.select_cohort(4)                   # v2 - v1 == 1 <= max_stale: warm
    assert srv.stats()["forced_inline"] == 1
    assert srv.stats()["served_warm"] == 2
    srv.update_embeddings([0], np.ones((1, d), np.float32))
    srv.select_cohort(4)                   # v3 - v1 == 2 > 1: forced inline
    st = srv.stats()
    assert st["forced_inline"] == 2
    assert st["streaming"]["served_version"] == 3


def test_unbounded_staleness_never_solves_inline_again():
    n, d = 48, 4
    srv = CohortServer(n, d, seed=0, config=CFG, solver=DummySolver(),
                       streaming=StreamingSpec(max_stale_versions=None))
    srv.update_embeddings(np.arange(n), np.ones((n, d), np.float32))
    srv.select_cohort(4)
    for v in range(10):                    # ten generations behind
        srv.update_embeddings([0], np.full((1, d), v, np.float32))
        srv.select_cohort(4)
    st = srv.stats()
    assert st["forced_inline"] == 1
    assert st["served_warm"] == 10


# -- admission control (satellite + tentpole) ------------------------------

def test_queue_depth_sheds_deterministically():
    adm = AdmissionController(max_queue_depth=2, name="t0")
    adm.try_admit()
    adm.try_admit()
    with pytest.raises(QueueFullError) as exc:
        adm.try_admit()
    assert exc.value.tenant == "t0"
    assert isinstance(exc.value, ShedError)
    adm.release()
    adm.try_admit()                        # freed depth re-admits
    assert adm.stats() == {"admitted": 3, "shed_queue": 1, "shed_rate": 0,
                           "depth": 2}


def test_token_bucket_sheds_and_refills_on_a_fake_clock():
    now = [0.0]
    adm = AdmissionController(rate_per_s=2.0, burst=2,
                              clock=lambda: now[0])
    adm.try_admit(), adm.release()
    adm.try_admit(), adm.release()
    with pytest.raises(RateLimitError):
        adm.try_admit()                    # bucket empty at t=0
    now[0] = 0.5                           # 0.5s * 2/s = one token back
    adm.try_admit()
    adm.release()
    with pytest.raises(RateLimitError):
        adm.try_admit()
    assert adm.stats()["shed_rate"] == 2


def test_frontend_sheds_past_configured_depth_with_typed_error():
    """One select parked inside the engine pins the tenant's only
    admission slot; the next select sheds with QueueFullError before
    touching any batching or engine state."""
    spec = StreamingSpec(max_queue_depth=1)
    fe = CohortFrontend([TenantSpec("vision", 48, 4, config=CFG,
                                    streaming=spec)])
    fe.update_embeddings("vision", np.arange(48),
                         np.ones((48, 4), np.float32))
    srv = fe.tenant("vision")
    entered, release = threading.Event(), threading.Event()
    orig = srv.engine.select_batched

    def slow(table, requests=1):
        entered.set()
        release.wait(timeout=30)
        return orig(table, requests=requests)

    srv.engine.select_batched = slow
    out = []
    worker = threading.Thread(
        target=lambda: out.append(fe.select_cohort("vision", 4)))
    worker.start()
    try:
        assert entered.wait(timeout=30)    # leader holds the one slot
        with pytest.raises(QueueFullError):
            fe.select_cohort("vision", 4)
    finally:
        release.set()
        worker.join(timeout=30)
    assert len(out) == 1
    assert fe.stats()["frontend"]["shed"] == 1
    fe.close()


# -- cross-tenant dedupe (tentpole) ----------------------------------------

def test_identical_fingerprint_tenants_ride_one_engine_solve():
    n, d = 64, 4
    fe = CohortFrontend(
        [TenantSpec(f"family-{i}", n, d, config=CFG, seed=i)
         for i in range(2)],
        streaming=StreamingSpec())
    try:
        x = np.random.default_rng(7).normal(size=(n, d)).astype(np.float32)
        for name in fe.tenant_names:
            fe.update_embeddings(name, np.arange(n), x)
        assert wait_until(
            lambda: all(fe.tenant(t).stats()["warm_ahead"] >= 1
                        for t in fe.tenant_names))
        stats = [fe.tenant(t).stats() for t in fe.tenant_names]
        # exactly ONE engine actually solved; the other adopted it
        assert sum(s["engine"]["cold_starts"] for s in stats) == 1
        assert sum(s["engine"]["solves"] for s in stats) == 1
        assert sum(s["dedupe_hit"] for s in stats) == 1
        assert fe.stats()["frontend"]["dedupe_hit"] == 1
        # both serve the warmed result without an inline solve
        for name in fe.tenant_names:
            fe.select_cohort(name, 8)
        assert all(fe.tenant(t).stats()["forced_inline"] == 0
                   for t in fe.tenant_names)
    finally:
        fe.close()


def test_different_configs_do_not_share_solves():
    n, d = 64, 4
    fe = CohortFrontend(
        [TenantSpec("a", n, d, config=CohortConfig(num_clusters=3)),
         TenantSpec("b", n, d, config=CohortConfig(num_clusters=4))],
        streaming=StreamingSpec())
    try:
        x = np.random.default_rng(7).normal(size=(n, d)).astype(np.float32)
        for name in fe.tenant_names:
            fe.update_embeddings(name, np.arange(n), x)
        assert wait_until(
            lambda: all(fe.tenant(t).stats()["warm_ahead"] >= 1
                        for t in fe.tenant_names))
        stats = [fe.tenant(t).stats() for t in fe.tenant_names]
        assert sum(s["engine"]["cold_starts"] for s in stats) == 2
        assert sum(s["dedupe_hit"] for s in stats) == 0
    finally:
        fe.close()


# -- graceful shutdown (satellite) -----------------------------------------

def test_frontend_close_drains_joins_and_rejects():
    n, d = 48, 4
    fe = CohortFrontend([TenantSpec("vision", n, d, config=CFG)],
                        streaming=StreamingSpec())
    fe.update_embeddings("vision", np.arange(n),
                         np.ones((n, d), np.float32))
    fe.select_cohort("vision", 4)
    solver = fe._solver
    fe.close()
    assert all(not t.is_alive() for t in solver._threads)
    with pytest.raises(ServiceClosedError):
        fe.select_cohort("vision", 4)
    with pytest.raises(ServiceClosedError):
        fe.tenant("vision").select_cohort(4)
    fe.close()                             # idempotent


def test_frontend_context_manager_closes():
    n, d = 48, 4
    with CohortFrontend([TenantSpec("vision", n, d, config=CFG)],
                        streaming=StreamingSpec()) as fe:
        fe.update_embeddings("vision", np.arange(n),
                             np.ones((n, d), np.float32))
        ids, _ = fe.select_cohort("vision", 4)
        assert len(ids) == 4
    with pytest.raises(ServiceClosedError):
        fe.select_cohort("vision", 4)


# -- background solver unit ------------------------------------------------

def test_background_solver_coalesces_per_key_latest_wins():
    ran = []
    gate = threading.Event()
    solver = BackgroundSolver(workers=1)
    try:
        solver.submit("block", gate.wait)  # occupy the single worker
        for i in range(5):                 # all coalesce onto one key
            solver.submit("t", lambda i=i: ran.append(i))
        gate.set()
        assert solver.drain(timeout=20)
        assert ran == [4]                  # only the latest-submitted ran
        assert solver.stats["coalesced"] == 4
    finally:
        solver.close(timeout=20)
    assert solver.submit("t", lambda: None) is False   # closed


def test_background_solver_task_error_is_counted_not_fatal():
    solver = BackgroundSolver(workers=1)
    try:
        solver.submit("bad", lambda: 1 / 0)
        assert wait_until(lambda: solver.stats["errors"] == 1)
        ran = []
        solver.submit("ok", lambda: ran.append(1))
        assert solver.drain(timeout=20)
        assert ran == [1]                  # worker survived the error
    finally:
        solver.close(timeout=20)


def test_solve_deduper_lead_wait_adopt_and_abort():
    dd = SolveDeduper(capacity=2)
    ticket, prep = dd.begin(b"fp1")
    assert ticket is not None and prep is None
    dd.complete(ticket, "solved-1")
    assert dd.begin(b"fp1") == (None, "solved-1")      # done-cache hit
    t2, _ = dd.begin(b"fp2")
    dd.abort(t2)
    t3, prep3 = dd.begin(b"fp2")           # abort left nothing behind
    assert t3 is not None and prep3 is None
    dd.complete(t3, "solved-2")
    assert dd.stats["leads"] == 3 and dd.stats["aborts"] == 1


# -- client-realism churn feeding the streaming path (satellite) -----------

def test_churn_trace_drives_streaming_updates_while_selects_run():
    """Trace-driven population churn (fed/realism.py) IS the delta
    stream the streaming path was built for: a ClientTrace's per-round
    (joined, left) ids feed ``update_embeddings`` from a writer thread
    while selector threads race it.  Invariants: every successful
    select is served from exactly one source (warm or forced-inline),
    the served version never moves backwards, and the only rejection
    surface is the typed ShedError the admission stats account for —
    no raw exceptions leak."""
    from repro.fed import ClientTrace, TraceSpec

    n, d, selectors, each = 64, 4, 4, 25
    srv = CohortServer(n, d, seed=0, config=CFG,
                       streaming=StreamingSpec(max_queue_depth=2))
    rng = np.random.default_rng(0)
    srv.update_embeddings(np.arange(n),
                          rng.normal(size=(n, d)).astype(np.float32))
    trace = ClientTrace(n, TraceSpec(p_join=0.5, p_leave=0.3), seed=9)

    stop = threading.Event()
    churn_updates = []

    def churner():
        r = 1
        fresh = np.random.default_rng(1)
        while not stop.is_set():
            joined, left = trace.churn_step(r)
            delta = np.concatenate([joined, left])
            if len(delta):
                # joins carry fresh embedding rows, leaves tombstone
                rows = np.zeros((len(delta), d), np.float32)
                rows[: len(joined)] = fresh.normal(
                    size=(len(joined), d)).astype(np.float32)
                srv.update_embeddings(delta, rows)
                churn_updates.append(r)
            r += 1
            time.sleep(0.001)

    ok, sheds, errors = [], [], []
    versions = {i: [] for i in range(selectors)}

    def selector(i):
        try:
            for _ in range(each):
                try:
                    ids, _ = srv.select_cohort(6)
                    assert len(ids) == 6
                    versions[i].append(
                        srv.stats()["streaming"]["served_version"])
                    ok.append(i)
                except ShedError:
                    sheds.append(i)
        except Exception as exc:        # pragma: no cover - failure path
            errors.append(exc)

    writer = threading.Thread(target=churner)
    threads = [threading.Thread(target=selector, args=(i,))
               for i in range(selectors)]
    writer.start()
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
    finally:
        stop.set()
        writer.join(timeout=30)
        srv.close()

    assert errors == []
    assert len(ok) + len(sheds) == selectors * each
    st = srv.stats()
    # every successful select was answered exactly once: warm or inline
    assert st["batches"] == st["served_warm"] + st["forced_inline"]
    assert st["batches"] == len(ok)
    # sheds reconcile with the admission accounting — nothing untyped
    assert st["shed"] == len(sheds)
    # the trace actually churned the table: one version bump per delta
    assert len(churn_updates) > 0
    assert st["updates"] == 1 + len(churn_updates)
    assert st["table_version"] == 1 + len(churn_updates)
    # each selector observed a non-decreasing served version
    for ix, seq in versions.items():
        assert all(a <= b for a, b in zip(seq, seq[1:])), f"selector {ix}"


# -- lock-order watchdog over the streaming herd (satellite) ---------------

def test_watchdog_instrumented_streaming_herd_obeys_lock_order():
    """Extends the frontend herd test to the background-solver publish
    edge: every lock in the streaming stack — server, tenant, frontend,
    shared solver, deduper, admission — swapped for rank-asserting
    OrderedLocks, then selects/updates/observes race the background
    warms.  Any acquisition against SERVING_LOCK_ORDER (e.g. a worker
    taking the select lock, or publish nesting into solve) raises
    LockOrderError deterministically."""
    from repro.analysis import instrument

    n, d = 96, 8
    fast_dqn = {"hidden": (32,), "eps_decay_steps": 30,
                "buffer_size": 512, "batch_size": 64}
    fe = CohortFrontend(
        [TenantSpec(f"family-{i}", n, d, config=CFG, seed=i,
                    policy="dqn", dqn_overrides=fast_dqn)
         for i in range(2)],
        streaming=StreamingSpec(max_stale_versions=2))
    assert instrument(fe) == ["_registry_lock"]
    assert instrument(fe._solver) == ["_queue_lock"]
    assert instrument(fe._deduper) == ["_dedupe_lock"]
    for name in fe.tenant_names:
        tenant = fe._tenants[name]
        assert instrument(tenant, prefix=f"{name}:") == ["lock"]
        assert sorted(instrument(tenant.server, prefix=f"{name}:")) == [
            "_publish_lock", "_select_lock", "_solve_lock",
            "_stats_lock", "_write_lock"]
        assert instrument(tenant.server.admission,
                          prefix=f"{name}:") == ["_admission_lock"]
    rng = np.random.default_rng(0)
    for name in fe.tenant_names:
        fe.update_embeddings(name, np.arange(n),
                             rng.normal(size=(n, d)).astype(np.float32))

    errors, done = [], []

    def hammer(i):
        name = fe.tenant_names[i % len(fe.tenant_names)]
        server = fe.tenant(name)
        local = np.random.default_rng(i)
        try:
            for _ in range(4):
                ids, _ = fe.select_cohort(name, 6)
                server.observe_round(0.5 + 0.01 * len(ids))
                server.update_embeddings(
                    ids, local.normal(size=(len(ids), d)).astype(np.float32))
                fe.stats()
            done.append(i)
        except Exception as exc:        # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert errors == []
    assert len(done) == 8
    # the background solver must not have tripped the watchdog either
    assert fe._solver.stats["errors"] == 0
    fe.close()
