"""Multi-device correctness of the sharded cohort engine.

Run single-device these tests exercise the shard_map path on a 1-way
mesh; the CI "sharded" job re-runs them under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so every psum /
padding / replication path sees a real 8-way mesh.  The subprocess test
forces the 8-device regime even from a single-device parent.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cohort import (CohortConfig, CohortEngine,
                          nystrom_from_landmarks,
                          sharded_nystrom_from_landmarks,
                          uniform_landmarks)
from repro.core.kmeans import kmeans
from repro.launch.mesh import make_cohort_mesh

KEY = jax.random.PRNGKey(0)


def blobs(n=509, k=4, sep=8.0, d=8, seed=0):
    # deliberately not divisible by typical mesh sizes: exercises the
    # pad-and-mask path on every run
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * sep
    labels = rng.integers(0, k, n)
    x = (centers[labels] + rng.normal(size=(n, d))).astype(np.float32)
    return x, labels


def same_partition(a, b):
    return bool(np.all((a[:, None] == a[None, :])
                       == (b[:, None] == b[None, :])))


def test_ci_forced_device_count_wiring():
    """When the CI sharded job forces 8 host devices, jax must see them
    (catches the flag being set after jax initialization)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count=8" in flags:
        assert len(jax.devices()) == 8


def test_sharded_allclose_to_single_device_nystrom():
    """Acceptance: identical landmarks + bandwidth -> the sharded path
    reproduces the single-device Nyström embedding to f32 reduction
    tolerance (spectrum) and the identical clustering."""
    x, _ = blobs()
    x = jnp.asarray(x)
    idx = uniform_landmarks(jax.random.PRNGKey(1), x, 64)
    y1, ev1, *_ = nystrom_from_landmarks(x, idx, 4, 0.05)
    y2, ev2, *_ = sharded_nystrom_from_landmarks(
        x, idx, 4, 0.05, make_cohort_mesh())
    np.testing.assert_allclose(np.asarray(ev1), np.asarray(ev2),
                               atol=1e-4)
    a1, _ = kmeans(jax.random.PRNGKey(2), y1, 4)
    a2, _ = kmeans(jax.random.PRNGKey(2), y2, 4)
    assert same_partition(np.asarray(a1), np.asarray(a2))


def test_engine_sharded_matches_nystrom_partition():
    """Same engine seed -> same content-derived keys -> same landmarks;
    the two methods must agree end-to-end (auto bandwidth included)."""
    x, labels = blobs()
    mk = lambda method: CohortEngine(
        CohortConfig(num_clusters=4, method=method, num_landmarks=64),
        seed=0)
    r1 = mk("nystrom").select(x)
    r2 = mk("sharded").select(x)
    assert r2.method == "sharded"
    assert same_partition(r1.assign, r2.assign)
    np.testing.assert_allclose(r1.evals, r2.evals, atol=5e-3)


def test_sharded_pallas_path_matches_jnp():
    """use_pallas must actually route through the kernels on the sharded
    path (regression: it used to be silently dropped) and agree with
    the jnp formula.  use_pallas now runs the streaming fused pipeline,
    whose tiled accumulation order differs from the materialized jnp
    composition — the partition and the leading (eigengap-informing)
    evals must still match tightly; the noise-dominated tail of the
    spectrum (near-null directions of W) gets a looser bound."""
    x, _ = blobs()
    k = 4
    mk = lambda pallas: CohortEngine(
        CohortConfig(num_clusters=k, method="sharded", num_landmarks=64,
                     use_pallas=pallas), seed=0)
    r_pal = mk(True).select(x)
    r_jnp = mk(False).select(x)
    assert same_partition(r_pal.assign, r_jnp.assign)
    # leading k evals (below the eigengap) are tightly pinned; from
    # index k upward the spectrum is the degenerate ~1 bulk, where the
    # near-null directions of W wander at the accumulation-order level
    np.testing.assert_allclose(r_pal.evals[:k], r_jnp.evals[:k], atol=1e-3)
    np.testing.assert_allclose(r_pal.evals, r_jnp.evals, atol=1e-2)


@pytest.mark.parametrize("affinity_dtype", ["f32", "bf16", "int8"])
def test_sharded_fused_quantized_matches_jnp_partition(affinity_dtype):
    """The streaming fused pipeline (use_pallas=True) at every tile
    precision must reproduce the jnp partition across the mesh — the
    per-shard fused accumulators compose with the two psums exactly
    like the materialized path (the last-step W⁻¹ᐟ² rotation is
    linear), including the padded-row masking on n=509."""
    x, labels = blobs()
    k = 4
    r_fused = CohortEngine(
        CohortConfig(num_clusters=k, method="sharded", num_landmarks=64,
                     use_pallas=True, affinity_dtype=affinity_dtype),
        seed=0).select(x)
    r_jnp = CohortEngine(
        CohortConfig(num_clusters=k, method="sharded", num_landmarks=64),
        seed=0).select(x)
    assert same_partition(r_fused.assign, r_jnp.assign)
    tol = 1e-3 if affinity_dtype == "f32" else 2e-2
    np.testing.assert_allclose(r_fused.evals[:k], r_jnp.evals[:k],
                               atol=tol)


def test_sharded_warm_start_equals_cold_start():
    """Warm-started sharded re-clustering after convergence must match a
    cold sharded solve on the same drifted embeddings."""
    x, _ = blobs()
    rng = np.random.default_rng(3)
    x2 = x + 0.01 * rng.normal(size=x.shape).astype(np.float32)
    cfg = lambda: CohortConfig(num_clusters=4, method="sharded",
                               num_landmarks=64, solver="subspace",
                               drift_threshold=0.1)
    warm_eng = CohortEngine(cfg(), seed=0)
    warm_eng.select(x)
    r_warm = warm_eng.select(x2)
    assert r_warm.source == "warm"
    r_cold = CohortEngine(cfg(), seed=0).select(x2)
    assert same_partition(r_warm.assign, r_cold.assign)
    np.testing.assert_allclose(r_warm.evals, r_cold.evals, atol=1e-2)


def test_cohort_server_dqn_policy_roundtrip_sharded():
    """DQN-policy serving through the sharded engine path: select ->
    observe_round -> drifted update, with consistent cohorts and
    advancing policy/engine stats (runs on the 8-way mesh in CI)."""
    from repro.launch.serve import CohortServer

    x, _ = blobs()
    n, d = x.shape
    srv = CohortServer(
        n, d, seed=0, policy="dqn",
        config=CohortConfig(num_clusters=4, method="sharded",
                            num_landmarks=64),
        dqn_overrides={"hidden": (32,), "eps_decay_steps": 10})
    srv.update_embeddings(np.arange(n), x)
    rng = np.random.default_rng(0)
    for r in range(3):
        ids, res = srv.select_cohort(16)
        assert res.method == "sharded"
        assert len(ids) == 16 and len(set(ids.tolist())) == 16
        srv.observe_round(0.5 + 0.1 * r)
        srv.update_embeddings(
            ids, srv.embeds[ids]
            + 0.01 * rng.normal(size=(16, d)).astype(np.float32))
    st = srv.stats()
    assert st["requests"] == 3 and st["rounds_observed"] == 3
    assert st["engine"]["solves"] == 3
    # drifted updates stay under the warm-start threshold
    assert st["engine"]["warm_starts"] >= 1
    assert st["policy"]["kind"] == "dqn"
    assert st["policy"]["train_calls"] == 3
    assert st["last_select"]["method"] == "sharded"


_SUBPROCESS_CHECK = """
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.cohort import nystrom_from_landmarks, \\
    sharded_nystrom_from_landmarks, uniform_landmarks
from repro.core.kmeans import kmeans
from repro.launch.mesh import make_cohort_mesh
rng = np.random.default_rng(0)
centers = rng.normal(size=(4, 8)) * 8
labels = rng.integers(0, 4, 509)
x = jnp.asarray((centers[labels]
                 + rng.normal(size=(509, 8))).astype(np.float32))
idx = uniform_landmarks(jax.random.PRNGKey(1), x, 64)
y1, ev1, *_ = nystrom_from_landmarks(x, idx, 4, 0.05)
y2, ev2, *_ = sharded_nystrom_from_landmarks(x, idx, 4, 0.05,
                                             make_cohort_mesh())
np.testing.assert_allclose(np.asarray(ev1), np.asarray(ev2), atol=1e-4)
a1, _ = kmeans(jax.random.PRNGKey(2), y1, 4)
a2, _ = kmeans(jax.random.PRNGKey(2), y2, 4)
a1, a2 = np.asarray(a1), np.asarray(a2)
assert np.all((a1[:, None] == a1[None, :]) == (a2[:, None] == a2[None, :]))
print("OK 8-device sharded == single-device")
"""


@pytest.mark.slow
def test_sharded_allclose_under_forced_8_host_devices():
    """Satellite: the 8-way mesh regime, regardless of parent devices.

    XLA flags must be set before jax initializes, so the check runs in a
    subprocess with the forced host-device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_CHECK],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr
    assert "OK 8-device sharded == single-device" in proc.stdout


@pytest.mark.slow
def test_cohort_engine_selects_100k_clients_sharded():
    """Acceptance: N = 100k cohort selection through the sharded engine
    (8-way host mesh in the CI sharded job)."""
    n, d, k = 100_000, 8, 8
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(k, d)).astype(np.float32) * 6
    labels = rng.integers(0, k, n)
    embeds = (centers[labels]
              + rng.normal(size=(n, d)).astype(np.float32))
    eng = CohortEngine(CohortConfig(num_clusters=k, method="sharded",
                                    num_landmarks=512), seed=0)
    res = eng.select(embeds)
    assert res.assign.shape == (n,)
    assert res.method == "sharded" and res.source == "cold"
    # every generator mode must land in its own non-trivial cluster
    assert len(np.unique(res.assign)) == k
    counts = np.bincount(res.assign, minlength=k)
    assert counts.min() > n // (4 * k)