"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'dev' extra (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core.kmeans import kmeans, pairwise_sq_dists
from repro.core.spectral import affinity_matrix, normalized_laplacian
from repro.fed.partition import partition_non_iid
from repro.fed.server import fedavg_aggregate
from repro.models import layers as L

KEY = jax.random.PRNGKey(0)
_settings = settings(max_examples=20, deadline=None)


@_settings
@given(st.integers(4, 40), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_pairwise_dists_nonneg_symmetric_zero_diag(n, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    dm = np.asarray(pairwise_sq_dists(x, x))
    assert (dm >= 0).all()
    np.testing.assert_allclose(dm, dm.T, atol=1e-4)
    assert np.abs(np.diag(dm)).max() < 1e-3


@_settings
@given(st.integers(6, 30), st.integers(2, 4), st.integers(0, 2 ** 31 - 1))
def test_kmeans_assignments_valid_and_exhaustive(n, k, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 3)) * 3
    assign, centers = kmeans(jax.random.PRNGKey(seed + 1), x, k)
    assign = np.asarray(assign)
    assert assign.min() >= 0 and assign.max() < k
    assert centers.shape == (k, 3)
    assert np.isfinite(np.asarray(centers)).all()


@_settings
@given(st.integers(5, 25), st.integers(0, 2 ** 31 - 1))
def test_laplacian_row_property(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 2))
    a = affinity_matrix(x, gamma=0.5)
    lap = np.asarray(normalized_laplacian(a))
    evals = np.linalg.eigvalsh(lap)
    assert evals.min() > -1e-4
    assert evals.max() < 2.0 + 1e-4            # normalized Laplacian bound


@_settings
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_fedavg_is_convex_combination(k, seed):
    rng = np.random.default_rng(seed)
    stacked = {"w": jnp.asarray(rng.normal(size=(k, 4)).astype(np.float32))}
    weights = jnp.asarray(rng.uniform(0.1, 1.0, k).astype(np.float32))
    out = np.asarray(fedavg_aggregate(stacked, weights)["w"])
    lo = np.asarray(stacked["w"]).min(axis=0) - 1e-5
    hi = np.asarray(stacked["w"]).max(axis=0) + 1e-5
    assert (out >= lo).all() and (out <= hi).all()


@_settings
@given(st.integers(2, 30), st.sampled_from([0.0, 0.5, 0.8, 1.0]),
       st.integers(0, 1000))
def test_partition_is_a_partition(num_clients, sigma, seed):
    y = np.random.default_rng(seed).integers(0, 10, 600).astype(np.int32)
    shards = partition_non_iid(y, num_clients, sigma, seed=seed,
                               min_per_client=1)
    assert len(shards) == num_clients
    all_idx = np.concatenate(shards)
    assert all_idx.min() >= 0 and all_idx.max() < len(y)
    # every sample assigned at least once (min-size top-up may duplicate)
    assert len(np.unique(all_idx)) >= len(y) * 0.99


@_settings
@given(st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_rope_is_orthogonal_transform(seq, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, seq, 1, 16))
    y = L.apply_rope(x, jnp.arange(seq))
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               atol=1e-4)


@_settings
@given(st.floats(0.1, 10.0), st.integers(0, 2 ** 31 - 1))
def test_rmsnorm_scale_invariance(scale, seed):
    p = L.rmsnorm_init(16, dtype="float32")
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, 16))
    y1 = np.asarray(L.rmsnorm(p, x))
    y2 = np.asarray(L.rmsnorm(p, x * scale))
    np.testing.assert_allclose(y1, y2, atol=1e-3)


@_settings
@given(st.integers(1, 8), st.integers(1, 16), st.integers(0, 2 ** 31 - 1))
def test_softmax_attention_rows_are_distributions(heads, seq, seed):
    from repro.kernels.ref import attention_ref
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (1, seq, heads, 8))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (1, seq, heads, 8))
    v = jnp.ones((1, seq, heads, 8))
    out = attention_ref(q, kk, v, causal=True)
    # with constant V, any valid attention average returns exactly V
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-4)
