"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the 'dev' extra (pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.core.kmeans import kmeans, pairwise_sq_dists
from repro.core.spectral import affinity_matrix, normalized_laplacian
from repro.fed.partition import partition_non_iid
from repro.fed.server import fedavg_aggregate
from repro.models import layers as L

KEY = jax.random.PRNGKey(0)
_settings = settings(max_examples=20, deadline=None)


@_settings
@given(st.integers(4, 40), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_pairwise_dists_nonneg_symmetric_zero_diag(n, d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    dm = np.asarray(pairwise_sq_dists(x, x))
    assert (dm >= 0).all()
    np.testing.assert_allclose(dm, dm.T, atol=1e-4)
    assert np.abs(np.diag(dm)).max() < 1e-3


@_settings
@given(st.integers(6, 30), st.integers(2, 4), st.integers(0, 2 ** 31 - 1))
def test_kmeans_assignments_valid_and_exhaustive(n, k, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 3)) * 3
    assign, centers = kmeans(jax.random.PRNGKey(seed + 1), x, k)
    assign = np.asarray(assign)
    assert assign.min() >= 0 and assign.max() < k
    assert centers.shape == (k, 3)
    assert np.isfinite(np.asarray(centers)).all()


@_settings
@given(st.integers(5, 25), st.integers(0, 2 ** 31 - 1))
def test_laplacian_row_property(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 2))
    a = affinity_matrix(x, gamma=0.5)
    lap = np.asarray(normalized_laplacian(a))
    evals = np.linalg.eigvalsh(lap)
    assert evals.min() > -1e-4
    assert evals.max() < 2.0 + 1e-4            # normalized Laplacian bound


@_settings
@given(st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_fedavg_is_convex_combination(k, seed):
    rng = np.random.default_rng(seed)
    stacked = {"w": jnp.asarray(rng.normal(size=(k, 4)).astype(np.float32))}
    weights = jnp.asarray(rng.uniform(0.1, 1.0, k).astype(np.float32))
    out = np.asarray(fedavg_aggregate(stacked, weights)["w"])
    lo = np.asarray(stacked["w"]).min(axis=0) - 1e-5
    hi = np.asarray(stacked["w"]).max(axis=0) + 1e-5
    assert (out >= lo).all() and (out <= hi).all()


@_settings
@given(st.integers(2, 30), st.sampled_from([0.0, 0.5, 0.8, 1.0]),
       st.integers(0, 1000))
def test_partition_is_a_partition(num_clients, sigma, seed):
    y = np.random.default_rng(seed).integers(0, 10, 600).astype(np.int32)
    shards = partition_non_iid(y, num_clients, sigma, seed=seed,
                               min_per_client=1)
    assert len(shards) == num_clients
    all_idx = np.concatenate(shards)
    assert all_idx.min() >= 0 and all_idx.max() < len(y)
    # every sample assigned at least once (min-size top-up may duplicate)
    assert len(np.unique(all_idx)) >= len(y) * 0.99


@_settings
@given(st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_rope_is_orthogonal_transform(seq, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, seq, 1, 16))
    y = L.apply_rope(x, jnp.arange(seq))
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               atol=1e-4)


@_settings
@given(st.floats(0.1, 10.0), st.integers(0, 2 ** 31 - 1))
def test_rmsnorm_scale_invariance(scale, seed):
    p = L.rmsnorm_init(16, dtype="float32")
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, 16))
    y1 = np.asarray(L.rmsnorm(p, x))
    y2 = np.asarray(L.rmsnorm(p, x * scale))
    np.testing.assert_allclose(y1, y2, atol=1e-3)


@_settings
@given(st.integers(1, 8), st.integers(1, 16), st.integers(0, 2 ** 31 - 1))
def test_softmax_attention_rows_are_distributions(heads, seq, seed):
    from repro.kernels.ref import attention_ref
    k = jax.random.PRNGKey(seed)
    q = jax.random.normal(k, (1, seq, heads, 8))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (1, seq, heads, 8))
    v = jnp.ones((1, seq, heads, 8))
    out = attention_ref(q, kk, v, causal=True)
    # with constant V, any valid attention average returns exactly V
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-4)


# -- client realism (fed/realism.py) ---------------------------------------

@_settings
@given(st.floats(-2.0, 2.0), st.floats(-1.0, 4.0), st.floats(1.0, 1e4),
       st.floats(0.0, 1e6), st.integers(0, 2 ** 31 - 1))
def test_availability_is_a_probability_for_arbitrary_params(
        floor, amplitude, period, t, seed):
    """Diurnal availability clips to [0, 1] no matter how pathological
    the floor/amplitude knobs are."""
    from repro.fed.realism import ClientTrace, TraceSpec

    spec = TraceSpec(availability="diurnal", avail_floor=floor,
                     avail_amplitude=amplitude, day_period_s=period)
    a = ClientTrace(12, spec, seed=seed).availability(t)
    assert a.shape == (12,)
    assert np.all(a >= 0.0) and np.all(a <= 1.0)


@_settings
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.0, 10.0),
       st.one_of(st.none(), st.floats(0.1, 20.0)), st.integers(0, 50))
def test_outcome_partitions_cohort_for_any_seed_and_hazard(
        seed, hazard, deadline, round_idx):
    """completed ∪ dropped == selected with no overlap, under any
    combination of dropout hazard, deadline, and chaos seed."""
    from repro.fed.realism import ClientTrace, RoundSpec, TraceSpec

    spec = TraceSpec(availability="diurnal", dropout_hazard=hazard,
                     tiers=(1.0, 3.0), p_join=0.2, p_leave=0.2)
    trace = ClientTrace(40, spec, seed=seed)
    sel = np.arange(1, 40, 2)
    out = trace.simulate_round(round_idx, 7.0 * round_idx, sel,
                               RoundSpec(deadline_s=deadline))
    merged = np.concatenate([out.completed, out.dropped])
    np.testing.assert_array_equal(np.sort(merged), np.sort(sel))
    assert len(np.intersect1d(out.completed, out.dropped)) == 0
    assert sum(out.reasons.values()) == len(out.dropped)
    assert out.elapsed_s >= 0.0
    if deadline is not None:
        assert out.elapsed_s <= deadline + 1e-9 \
            or out.reasons["deadline"] == 0


@_settings
@given(st.floats(1.0, 50.0), st.floats(1.0, 4.0),
       st.integers(0, 2 ** 31 - 1))
def test_round_wall_time_monotone_in_straggler_stretch(
        stretch, factor, seed):
    """Stretching the slow tier can only lengthen the simulated round:
    wall time is monotone non-decreasing in the tier stretch (hazard
    and availability off, so only latency moves)."""
    from repro.fed.realism import ClientTrace, RoundSpec, TraceSpec

    n = 16
    assign = tuple(i % 2 for i in range(n))
    sel = np.arange(n)

    def elapsed(mult):
        spec = TraceSpec(tiers=(1.0, mult), tier_assign=assign)
        trace = ClientTrace(n, spec, seed=seed)
        return trace.simulate_round(0, 0.0, sel, RoundSpec()).elapsed_s

    assert elapsed(stretch * factor) >= elapsed(stretch) - 1e-12
