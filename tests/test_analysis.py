"""repro-lint: fixture corpus, suppressions, baseline, watchdog, dogfood.

Tier-1.  The analyzer itself is stdlib-only (``repro.analysis`` imports
no jax), so most of this file runs in milliseconds; the dogfood
regression tests at the bottom exercise the real serving classes.
"""

import json
import pathlib
import threading

import pytest

from repro.analysis import (LockOrderError, OrderedLock, RULES,
                            SERVING_LOCK_ORDER, analyze_paths, instrument)
from repro.analysis.findings import (Finding, Suppressions, apply_baseline,
                                     load_baseline, save_baseline)
from repro.analysis.runner import main as lint_main
from repro.analysis import watchdog

REPO = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "analysis_fixtures"


def run_lint(*relpaths):
    return analyze_paths([str(FIXTURES / p) for p in relpaths], root=REPO)


def rules_of(findings):
    return {f.rule for f in findings}


# -- rule catalog ----------------------------------------------------------

def test_every_rule_documented():
    assert len(RULES) >= 11
    for rule, desc in RULES.items():
        assert rule == rule.lower() and " " not in rule
        assert len(desc) > 20


# -- purity / PRNG family --------------------------------------------------

def test_purity_bad_flags_every_rule():
    fs = run_lint("purity_bad.py")
    assert rules_of(fs) == {"jax-host-time", "jax-host-random",
                            "jax-host-sync", "prng-constant-key",
                            "prng-key-reuse", "jax-blocking-sync"}


def test_purity_bad_specific_sites():
    fs = run_lint("purity_bad.py")
    by_rule = {}
    for f in fs:
        by_rule.setdefault(f.rule, []).append(f)
    # three distinct sync shapes: .item(), float(), np.asarray()
    assert len(by_rule["jax-host-sync"]) == 3
    # stdlib random + np.random
    assert len(by_rule["jax-host-random"]) == 2
    # reachability: _helper is flagged although not itself decorated
    assert any(f.symbol == "_helper" for f in by_rule["jax-host-time"])
    # the blocking sync names the jitted producer line
    (block,) = by_rule["jax-blocking-sync"]
    assert block.symbol == "hot_path" and "float" in block.message


def test_purity_good_is_clean():
    assert run_lint("purity_good.py") == []


# -- pallas family ---------------------------------------------------------

def test_pallas_bad_flags_all_three_rules():
    fs = run_lint("pallas_bad")
    assert rules_of(fs) == {"pallas-interpret", "pallas-static-args",
                            "pallas-ref-oracle"}
    oracle = next(f for f in fs if f.rule == "pallas-ref-oracle")
    assert "shift_ref" in oracle.message


def test_pallas_good_is_clean():
    assert run_lint("pallas_good") == []


# -- lock family -----------------------------------------------------------

def test_locks_bad_flags_guard_and_cycle():
    fs = run_lint("locks_bad.py")
    assert rules_of(fs) == {"lock-guarded-by", "lock-order-cycle"}
    guards = [f for f in fs if f.rule == "lock-guarded-by"]
    # plain assignment AND container-mutator call, but NOT the held
    # one — and exactly one finding per site (no Subscript/Attribute
    # double report)
    assert sorted(g.symbol for g in guards) == [
        "BadServer.unguarded_mutation", "BadServer.unguarded_mutator_call"]
    cycle = next(f for f in fs if f.rule == "lock-order-cycle")
    assert "_a_lock" in cycle.message and "_b_lock" in cycle.message


def test_locks_good_is_clean():
    assert run_lint("locks_good.py") == []


# -- suppressions ----------------------------------------------------------

def test_suppressions_silence_listed_rules_only():
    fs = run_lint("suppressed.py")
    # the only surviving finding is the one whose suppression names a
    # different rule
    assert [(f.rule, f.symbol) for f in fs] == [
        ("jax-host-time", "wrong_rule_listed")]


def test_suppression_comment_only_line_covers_next_line():
    s = Suppressions("# repro-lint: ignore[some-rule]\nx = 1\n")
    assert s.covers(1, "some-rule") and s.covers(2, "some-rule")
    assert not s.covers(2, "other-rule")


# -- baseline --------------------------------------------------------------

def test_baseline_add_and_expire_roundtrip(tmp_path):
    findings = run_lint("purity_bad.py")
    assert findings
    path = tmp_path / "baseline.json"
    save_baseline(path, findings)
    baseline = load_baseline(path)
    assert len(baseline) == len(findings)

    # grandfathered: nothing new, nothing stale
    new, stale = apply_baseline(findings, baseline)
    assert new == [] and stale == []

    # a fresh finding is new; a fixed finding leaves a stale entry
    extra = Finding(rule="jax-host-time", path="x.py", line=1,
                    message="m", symbol="f", source="t = time.time()")
    new, stale = apply_baseline(findings[1:] + [extra], baseline)
    assert new == [extra]
    assert [e["fingerprint"] for e in stale] == [
        findings[0].fingerprint()]


def test_baseline_fingerprint_survives_line_churn():
    a = Finding(rule="r", path="p.py", line=10, message="m",
                symbol="f", source="x = 1")
    b = Finding(rule="r", path="p.py", line=99, message="m (moved)",
                symbol="f", source="x = 1")
    assert a.fingerprint() == b.fingerprint()


def test_runner_check_mode_end_to_end(tmp_path, capsys):
    bad = str(FIXTURES / "purity_bad.py")
    base = str(tmp_path / "b.json")
    # no baseline: findings -> exit 1
    assert lint_main([bad, "--check", "--baseline", base]) == 1
    # grandfather them, then --check passes
    assert lint_main([bad, "--update-baseline", "--baseline", base]) == 0
    assert lint_main([bad, "--check", "--baseline", base]) == 0
    # --json emits a machine-readable summary
    capsys.readouterr()                       # drain the text output
    assert lint_main([bad, "--json", "--baseline", base]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["findings"] == []
    assert payload["baselined"] == payload["total"] > 0


def test_repo_src_is_clean_against_committed_baseline():
    """The dogfooded tree must lint clean (CI runs the same gate)."""
    findings = analyze_paths(["src"], root=REPO)
    baseline = load_baseline(REPO / ".repro-lint-baseline.json")
    new, _ = apply_baseline(findings, baseline)
    assert new == [], "\n".join(f.render() for f in new)
    assert len(baseline) <= 5          # acceptance: tiny or empty


# -- runtime watchdog ------------------------------------------------------

def test_ordered_lock_allows_declared_order():
    a = OrderedLock("a", 10)
    b = OrderedLock("b", 20)
    with a:
        with b:
            assert watchdog.held_names() == ["a", "b"]
    assert watchdog.held_names() == []


def test_ordered_lock_rejects_inversion_and_reentry():
    a = OrderedLock("a", 10)
    b = OrderedLock("b", 20)
    with b:
        with pytest.raises(LockOrderError):
            a.acquire()
    with a:
        with pytest.raises(LockOrderError):   # equal rank == reentry
            a.acquire()
    # stacks unwound cleanly after the failures
    assert watchdog.held_names() == []


def test_ordered_lock_is_per_thread():
    # held stacks are thread-local: while the main thread holds a
    # rank-20 lock, another thread may still start at rank 10 (with its
    # own lock instances — a shared global stack would raise here)
    b = OrderedLock("b", 20)
    a2, b2 = OrderedLock("a2", 10), OrderedLock("b2", 20)
    errors = []

    def other():
        try:
            with a2:
                with b2:
                    pass
        except Exception as e:          # pragma: no cover
            errors.append(e)

    with b:
        t = threading.Thread(target=other)
        t.start()
        t.join(timeout=10)
    assert errors == []


def test_instrument_swaps_lock_attributes():
    class Obj:
        def __init__(self):
            self._write_lock = threading.Lock()
            self._select_lock = threading.Lock()
            self.not_a_lock = 3

    o = Obj()
    done = instrument(o, prefix="t0:")
    assert sorted(done) == ["_select_lock", "_write_lock"]
    assert isinstance(o._write_lock, OrderedLock)
    assert o._write_lock.rank == SERVING_LOCK_ORDER["_write_lock"]
    assert o.not_a_lock == 3
    with o._select_lock:
        with o._write_lock:             # declared order: select < write
            pass
    with pytest.raises(LockOrderError):
        with o._write_lock:
            with o._select_lock:
                pass
