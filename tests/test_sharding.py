"""Sharding rule engine + distributed-equivalence via subprocess.

The rule tests run in-process (pure functions of shapes); the
multi-device tests spawn a subprocess with forced host devices because
jax locks the device count at first init.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.sharding import constrain, params_pspecs

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 4}


def test_rules_shard_ffn_and_embed():
    params = {
        "embed": {"w": jax.ShapeDtypeStruct((512, 64), "float32")},
        "segments": [{"blocks": ({
            "ffn": {"gate": {"w": jax.ShapeDtypeStruct((2, 64, 256), "float32")},
                    "down": {"w": jax.ShapeDtypeStruct((2, 256, 64), "float32")}},
            "mixer_norm": {"scale": jax.ShapeDtypeStruct((64,), "float32")},
        },)}],
    }
    specs = params_pspecs(params, FakeMesh())
    assert specs["embed"]["w"] == P("model", "data")
    blk = specs["segments"][0]["blocks"][0]
    assert blk["ffn"]["gate"]["w"] == P(None, "data", "model")
    assert blk["ffn"]["down"]["w"] == P(None, "model", "data")
    assert blk["mixer_norm"]["scale"] == P()


def test_rules_respect_divisibility():
    params = {"ffn": {"gate": {"w": jax.ShapeDtypeStruct((7, 9), "float32")}}}
    specs = params_pspecs(params, FakeMesh())
    assert specs["ffn"]["gate"]["w"] == P(None, None)   # 7,9 not divisible


def test_moe_expert_sharding():
    """H2 layout: experts over 'data' (expert parallelism), ff over
    'model' — expert weights stay out of the FSDP gather path."""
    params = {"moe": {"experts": {
        "gate": jax.ShapeDtypeStruct((8, 64, 128), "float32"),
        "down": jax.ShapeDtypeStruct((8, 128, 64), "float32")}}}
    specs = params_pspecs(params, FakeMesh())
    assert specs["moe"]["experts"]["gate"] == P("data", None, "model")
    assert specs["moe"]["experts"]["down"] == P("data", "model", None)


def test_constrain_is_identity_without_mesh():
    x = jax.numpy.ones((4, 4))
    y = constrain(x, "data", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


_SUBPROCESS_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, sys.argv[1])
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import build_step, make_optimizer, make_train_step
    from repro.models import transformer as T
    from repro.models.sharding import use_mesh, params_shardings

    cfg = get_config("qwen2-7b").reduced()
    shape = ShapeConfig("t", 16, 8, "train", 2)
    opt = make_optimizer(cfg, 10, state_dtype="float32")
    step = make_train_step(cfg, shape, opt)
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    opt_state = opt.init(params)
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}

    # single-device result
    p1, _, m1 = jax.jit(step)(params, opt_state, jnp.int32(0), batch)
    loss1 = float(m1["loss"])

    # sharded result on a 4x2 mesh
    mesh = make_test_mesh(data=4, model=2)
    with use_mesh(mesh):
        shard = params_shardings(params, mesh)
        params_s = jax.device_put(params, shard)
        opt_s = jax.device_put(opt_state, params_shardings(opt_state, mesh))
        p2, _, m2 = jax.jit(step)(params_s, opt_s, jnp.int32(0), batch)
    loss2 = float(m2["loss"])
    d = max(float(jnp.abs(a - b).max()) for a, b in
            zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    print("RESULT", loss1, loss2, d)
    assert abs(loss1 - loss2) < 1e-3, (loss1, loss2)
    assert d < 2e-2, d
""")


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """Distributed semantics: the sharded train step must be numerically
    equivalent to the single-device step (GSPMD is a compiler detail)."""
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_EQUIV, SRC],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "RESULT" in r.stdout


_SUBPROCESS_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, sys.argv[1])
    import jax
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import build_step, lower_step

    mesh = make_test_mesh(data=2, model=2, pod=2)
    cfg = get_config(sys.argv[2]).reduced()
    for shape in [ShapeConfig("t", 32, 8, "train", 2),
                  ShapeConfig("d", 64, 1, "decode")]:
        b = build_step(cfg, shape, mesh)
        c = lower_step(b, mesh).compile()
        assert c.memory_analysis() is not None
    print("OK")
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "deepseek-v3-671b",
                                  "seamless-m4t-medium"])
def test_multipod_mesh_lowering_smoke(arch):
    """Reduced configs must lower+compile on a 3-axis (pod,data,model)
    mesh — the structural core of the multi-pod dry-run."""
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_DRYRUN, SRC, arch],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "OK" in r.stdout
