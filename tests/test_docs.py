"""Docs stay runnable: every ```python snippet in README/docs executes.

The CI docs job runs ``scripts/check_docs.py`` standalone; this test
keeps the same guarantee inside the tier-1 suite so a snippet-breaking
change fails locally too.
"""

import importlib.util
import pathlib

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
spec = importlib.util.spec_from_file_location(
    "check_docs", REPO / "scripts" / "check_docs.py")
check_docs = importlib.util.module_from_spec(spec)
spec.loader.exec_module(check_docs)


def test_doc_files_discovered():
    names = {p.name for p in check_docs.doc_files()}
    assert {"README.md", "ARCHITECTURE.md", "BENCHMARKS.md"} <= names


@pytest.mark.parametrize("path", check_docs.doc_files(),
                         ids=lambda p: p.name)
def test_doc_snippets_run(path, tmp_path, monkeypatch):
    monkeypatch.chdir(REPO)          # snippets resolve repo-root paths
    assert check_docs.run_file(path) >= 0
