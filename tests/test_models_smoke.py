"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates its REDUCED variant (<=2 layers,
d_model<=256, <=4 experts — same structural features as the full config)
and runs one forward + one train step on CPU, asserting output shapes and
no NaNs.  Full configs are exercised only via the dry-run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.base import ShapeConfig
from repro.launch.steps import make_optimizer, make_train_step
from repro.models import encdec as ED
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
B, S = 2, 16

# deepseek-v3-671b is by far the heaviest reduced config (~24s for its
# two smoke tests); keep it out of the default tier-1 budget — the CI
# slow lane and the dry-run still exercise it
_SMOKE_ARCHS = [pytest.param(a, marks=pytest.mark.slow)
                if a == "deepseek-v3-671b" else a for a in list_archs()]


def _batch(cfg, *, with_labels=True):
    b = {}
    if cfg.is_encoder_decoder:
        b["src_embeds"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    if cfg.num_prefix_embeds:
        b["prefix_embeds"] = jax.random.normal(
            KEY, (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    b["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if with_labels:
        b["labels"] = jax.random.randint(
            jax.random.fold_in(KEY, 1), (B, S), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", _SMOKE_ARCHS)
def test_reduced_forward_and_decode(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 256
    assert cfg.num_experts <= 4
    batch = _batch(cfg)
    if cfg.is_encoder_decoder:
        params = ED.init_encdec(KEY, cfg)
        caches = ED.init_encdec_cache(cfg, B, S + 4)
        logits, caches = ED.encdec_prefill(params, cfg, batch, caches)
        step_logits, _ = ED.encdec_decode_step(
            params, cfg, jnp.ones((B, 1), jnp.int32), caches, jnp.int32(S))
    else:
        params = T.init_lm(KEY, cfg)
        caches = T.init_lm_cache(cfg, B, S + cfg.num_prefix_embeds + 4)
        logits, caches = T.lm_prefill(params, cfg, batch, caches)
        step_logits, _ = T.lm_decode_step(
            params, cfg, jnp.ones((B, 1), jnp.int32), caches,
            jnp.int32(S + cfg.num_prefix_embeds))
    assert logits.shape == (B, cfg.vocab_size)
    assert step_logits.shape == (B, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
    assert not np.isnan(np.asarray(step_logits)).any()


@pytest.mark.parametrize("arch", _SMOKE_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    shape = ShapeConfig("smoke_train", S, B, "train", num_microbatches=2)
    opt = make_optimizer(cfg, 10, state_dtype="float32")
    step_fn = make_train_step(cfg, shape, opt)
    init = ED.init_encdec if cfg.is_encoder_decoder else T.init_lm
    params = init(KEY, cfg)
    opt_state = opt.init(params)
    batch = _batch(cfg)
    params2, opt_state2, metrics = jax.jit(step_fn)(
        params, opt_state, jnp.int32(0), batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # parameters actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, params2)
    assert max(jax.tree.leaves(moved)) > 0
    # second step decreases loss on average over a few steps
    for i in range(1, 3):
        params2, opt_state2, metrics = jax.jit(step_fn)(
            params2, opt_state2, jnp.int32(i), batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_matches_assignment(arch):
    """Exact assigned hyper-parameters survive in the full configs."""
    spec = {
        "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=14336, vocab_size=65536,
                               num_experts=16, experts_per_token=2),
        "deepseek-v3-671b": dict(num_layers=61, d_model=7168, num_heads=128,
                                 vocab_size=129280, num_experts=256,
                                 experts_per_token=8),
        "moonshot-v1-16b-a3b": dict(num_layers=48, d_model=2048,
                                    num_heads=16, num_kv_heads=16,
                                    vocab_size=163840, num_experts=64,
                                    experts_per_token=6, moe_d_ff=1408),
        "mamba2-2.7b": dict(num_layers=64, d_model=2560, d_ff=0,
                            vocab_size=50280),
        "llama4-scout-17b-a16e": dict(num_layers=48, d_model=5120,
                                      num_heads=40, num_kv_heads=8,
                                      vocab_size=202048, num_experts=16,
                                      experts_per_token=1, moe_d_ff=8192),
        "qwen3-14b": dict(num_layers=40, d_model=5120, num_heads=40,
                          num_kv_heads=8, d_ff=17408, vocab_size=151936,
                          qk_norm=True),
        "seamless-m4t-medium": dict(num_layers=12, d_model=1024,
                                    num_heads=16, num_kv_heads=16,
                                    d_ff=4096, vocab_size=256206,
                                    is_encoder_decoder=True),
        "gemma-2b": dict(num_layers=18, d_model=2048, num_heads=8,
                         num_kv_heads=1, head_dim=256, d_ff=16384,
                         vocab_size=256000),
        "internvl2-26b": dict(num_layers=48, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab_size=92553),
        "qwen2-7b": dict(num_layers=28, d_model=3584, num_heads=28,
                         num_kv_heads=4, d_ff=18944, vocab_size=152064,
                         qkv_bias=True),
    }[arch]
    cfg = get_config(arch)
    for k, v in spec.items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
    if cfg.ssm is not None and arch == "mamba2-2.7b":
        assert cfg.ssm.d_state == 128
