"""Policy serving: ClusterPolicy learning + the hardened CohortServer."""

import threading
import time

import numpy as np
import pytest

from repro.cohort import CohortConfig
from repro.launch.serve import CohortServer
from repro.policy import ClusterPolicy

FAST_DQN = {"hidden": (32,), "eps_decay_steps": 30, "buffer_size": 512,
            "batch_size": 64}


def blob_table(n=120, k=3, d=8, sep=8.0, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)).astype(np.float32) * sep
    true = rng.integers(0, k, n)
    x = (centers[true] + rng.normal(size=(n, d)).astype(np.float32))
    return x, true


def mk_server(n=120, k=3, d=8, policy="dqn", seed=0, **cfg_kw):
    x, true = blob_table(n, k, d, seed=seed)
    srv = CohortServer(n, d, seed=seed, policy=policy,
                       config=CohortConfig(num_clusters=k, **cfg_kw),
                       dqn_overrides=FAST_DQN if policy == "dqn" else None)
    srv.update_embeddings(np.arange(n), x)
    return srv, true


# -- ClusterPolicy (Algorithm II in isolation) ---------------------------

def test_cluster_policy_learns_to_avoid_zero_reward_cluster():
    """Acceptance: trained on synthetic rewards where cluster 0 pays
    nothing, the policy's draw weights shift away from cluster 0."""
    k = 3
    pol = ClusterPolicy(k, state_dim=4, seed=0, dqn_overrides=FAST_DQN)
    rng = np.random.default_rng(0)
    s = np.ones(4, np.float32)
    for _ in range(120):
        for a in range(k):
            pol.observe(s, [a], 0.0 if a == 0 else 1.0, s)
        pol.train(rng)
    pol.agent.steps = 10_000            # decay ε to eps_end
    w = pol.draw_weights(s)
    assert w.shape == (k,) and abs(w.sum() - 1.0) < 1e-9
    assert w[0] < 1.0 / k               # shifted away from zero reward
    assert int(np.argmax(w)) != 0


def test_cluster_policy_draw_contract():
    """draw() honors pools: unique clients, no empty-cluster picks,
    actions aligned with picked slots."""
    k = 4
    pol = ClusterPolicy(k, state_dim=3, seed=0, dqn_overrides=FAST_DQN)
    rng = np.random.default_rng(0)
    pools = {0: list(range(0, 5)), 1: list(range(5, 10)),
             2: [], 3: list(range(10, 12))}
    picked, actions = pol.draw(rng, np.zeros(3, np.float32), pools, 8)
    assert len(picked) == 8 == len(actions)
    assert len(set(picked)) == 8
    assert 2 not in actions             # empty cluster never credited
    # pool exhaustion: asking for more than exists returns what's there
    pools = {c: ([0, 1] if c == 0 else []) for c in range(k)}
    picked, actions = pol.draw(rng, np.zeros(3, np.float32), pools, 8)
    assert sorted(picked) == [0, 1]


# -- CohortServer: DQN-policy serving ------------------------------------

def test_cohort_server_dqn_shifts_draws_from_stale_cluster():
    """Acceptance criterion: serving with --policy dqn, a synthetic
    reward that pays nothing for 'stale' clients (true cluster 0) pushes
    the learned draw weights away from the engine cluster covering them."""
    srv, true = mk_server()
    k = srv.config.num_clusters
    for _ in range(60):
        ids, res = srv.select_cohort(12)
        useful = float(np.mean(true[ids] != 0)) if len(ids) else 0.0
        srv.observe_round(0.5 + 0.4 * useful)
    # engine cluster holding the majority of true-cluster-0 clients
    assign = srv.engine.state.result.assign
    stale = int(np.argmax(np.bincount(assign[true == 0], minlength=k)))
    srv.policy.agent.steps = 10_000     # read weights at ε = eps_end
    w = srv.policy.draw_weights(srv._policy_state(assign, srv.embeds))
    assert w[stale] < 1.0 / k
    assert int(np.argmax(w)) != stale


def test_cohort_server_dqn_roundtrip_counters():
    """stats() reports advancing engine/policy/latency counters."""
    srv, true = mk_server()
    for r in range(3):
        ids, res = srv.select_cohort(10)
        assert len(ids) == 10 and len(set(ids.tolist())) == 10
        srv.observe_round(0.6, timings={"select": 0.01, "train": 0.2})
    st = srv.stats()
    assert st["requests"] == 3
    assert st["rounds_observed"] == 3
    assert st["engine"]["solves"] >= 1
    assert st["engine"]["cache_hits"] == 2       # same table, cached
    assert st["latency_s"]["total_s"] > 0
    assert st["round_timings_s"]["train"] == pytest.approx(0.2)
    assert st["last_select"]["method"] == "dense"
    assert 0.0 <= st["policy"]["epsilon"] <= 1.0
    assert st["policy"]["buffer_size"] > 0
    assert st["policy"]["train_calls"] == 3
    assert st["dropped_transitions"] == 0
    # a second select before the round report replaces the parked
    # transition — observable, not silent
    srv.select_cohort(10)
    srv.select_cohort(10)
    assert srv.stats()["dropped_transitions"] == 1


def test_cohort_server_stratified_unchanged_contract():
    """The default policy still serves de-biased round-robin cohorts."""
    srv, _ = mk_server(policy="stratified")
    ids, res = srv.select_cohort(9)
    assert len(ids) == 9 and len(set(ids.tolist())) == 9
    # round-robin over k=3 clusters -> 3 from each
    counts = np.bincount(res.assign[ids], minlength=res.k)
    assert counts.max() - counts.min() <= 1
    st = srv.stats()
    assert st["policy"] == {"kind": "stratified"}


def test_cohort_server_rejects_unknown_policy():
    with pytest.raises(ValueError, match="unknown policy"):
        CohortServer(10, 4, policy="bandit")


# -- CohortServer: versioned copy-on-write table -------------------------

def test_cohort_server_snapshot_versioning_and_immutability():
    srv, _ = mk_server(policy="stratified")
    v0, table0 = srv.snapshot()
    with pytest.raises(ValueError):
        table0[0, 0] = 1.0              # snapshots are frozen
    srv.update_embeddings([0], np.ones((1, 8), np.float32))
    v1, table1 = srv.snapshot()
    assert v1 == v0 + 1
    assert table1 is not table0         # copy-on-write, not in-place
    assert table0[0, 0] != 1.0          # old snapshot untouched
    assert table1[0, 0] == 1.0


def test_cohort_server_concurrent_update_select_no_torn_reads():
    """Interleaved update_embeddings/select_cohort: the table a solve
    clusters must be one consistent version, never a half-written mix."""
    n, d = 96, 4
    base, _ = blob_table(n=n, k=3, d=d, seed=1)
    srv = CohortServer(n, d, seed=0, policy="stratified",
                       config=CohortConfig(num_clusters=3))
    srv.update_embeddings(np.arange(n), base)

    torn = []
    orig_select = srv.engine.select

    def spy(embeds, **kw):
        before = np.array(embeds, copy=True)
        time.sleep(0.01)                 # widen the race window
        if not np.array_equal(before, np.asarray(embeds)):
            torn.append("snapshot mutated under reader")
        # version consistency: the table must be bit-identical to ONE
        # writer version base + 0.001*v (same float32 op as the writer),
        # never a mix of rows from different versions
        offsets = np.asarray(embeds) - base
        v_est = int(round(float(offsets.mean()) / 0.001))
        if not np.array_equal(np.asarray(embeds),
                              base + np.float32(0.001 * v_est)):
            torn.append("mixed-version table")
        return orig_select(before, **kw)

    srv.engine.select = spy
    stop = threading.Event()

    def writer():
        v = 0
        while not stop.is_set():
            v += 1
            srv.update_embeddings(np.arange(n),
                                  base + np.float32(0.001 * v))

    th = threading.Thread(target=writer)
    th.start()
    try:
        for _ in range(5):
            ids, res = srv.select_cohort(6)
            assert len(ids) == 6
            assert res.assign.shape == (n,)
    finally:
        stop.set()
        th.join()
    assert not torn, torn
    assert srv.version > 0
    assert srv.stats()["updates"] == srv.version


def test_cluster_policy_train_returns_device_scalar_lazy_loss():
    """Regression (repro-lint jax-blocking-sync): train() must not force
    a host sync under the server's select lock; stats() materializes
    the loss lazily through the last_loss property."""
    pol = ClusterPolicy(3, state_dim=10, seed=0, dqn_overrides=FAST_DQN)
    rng = np.random.default_rng(0)
    s = rng.normal(size=10).astype(np.float32)
    for _ in range(16):
        pol.observe(s, [int(rng.integers(3))], 1.0, s)
    out = pol.train(rng)
    assert not isinstance(out, float)          # device scalar
    assert isinstance(pol.last_loss, float)    # lazy materialization
    assert pol.stats()["last_loss"] == pol.last_loss


def test_cohort_server_stats_are_lock_protected_snapshots():
    """Regression (repro-lint lock-guarded-by): dashboard counters live
    behind their own _stats_lock, and stats() hands back copies —
    mutating the returned dicts must not corrupt the live state."""
    server, _ = mk_server(policy="stratified")
    server.select_cohort(8)
    st = server.stats()
    st["latency_s"]["total_s"] = -1.0
    st["round_timings_s"]["bogus"] = 1.0
    st["requests"] = 10**6
    st2 = server.stats()
    assert st2["latency_s"]["total_s"] >= 0.0
    assert "bogus" not in st2["round_timings_s"]
    assert st2["requests"] == 1
    # counters shared by the update path and the select path still agree
    server.update_embeddings(np.arange(4), np.zeros((4, 8), np.float32))
    assert server.stats()["updates"] == 2      # mk_server seeded 1 update
