import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adam, adamw, sgd, clip_by_global_norm,
                         cosine_schedule, linear_warmup_cosine)


def quad_loss(params):
    return jnp.sum((params["x"] - 3.0) ** 2) + jnp.sum((params["y"] + 1) ** 2)


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.1), lambda: sgd(0.05, momentum=0.9),
    lambda: adam(0.1), lambda: adamw(0.1, weight_decay=0.0)])
def test_optimizers_converge_on_quadratic(make_opt):
    opt = make_opt()
    params = {"x": jnp.zeros(3), "y": jnp.ones(2)}
    state = opt.init(params)
    for step in range(200):
        grads = jax.grad(quad_loss)(params)
        params, state = opt.update(grads, state, params, jnp.int32(step))
    assert float(quad_loss(params)) < 1e-2


def test_adam_bf16_state_dtype():
    opt = adam(0.1, state_dtype="bfloat16")
    params = {"x": jnp.zeros(4)}
    state = opt.init(params)
    assert state["m"]["x"].dtype == jnp.bfloat16
    grads = {"x": jnp.ones(4)}
    params, state = opt.update(grads, state, params, jnp.int32(0))
    assert np.isfinite(np.asarray(params["x"])).all()


def test_clip_by_global_norm():
    grads = {"a": jnp.ones(4) * 10}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(total) == pytest.approx(1.0, rel=1e-3)
    # below threshold: untouched
    small = {"a": jnp.ones(4) * 0.1}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 0.1, rtol=1e-5)


def test_schedules():
    cos = cosine_schedule(1.0, 100)
    assert float(cos(0)) == pytest.approx(1.0)
    assert float(cos(100)) == pytest.approx(0.1, abs=1e-3)
    warm = linear_warmup_cosine(1.0, 10, 110)
    assert float(warm(0)) < float(warm(9)) <= 1.0
    assert float(warm(9)) == pytest.approx(1.0)
