"""Mamba2 SSD: chunked algorithm vs step-by-step recurrence oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import mamba as M

KEY = jax.random.PRNGKey(0)


def mk_cfg(chunk=8, state=8, head_dim=8):
    return ModelConfig(name="m", arch_type="ssm", num_layers=1,
                       d_model=32, num_heads=0, num_kv_heads=0, head_dim=0,
                       d_ff=0, vocab_size=64, attn_period=0,
                       ssm=SSMConfig(d_state=state, head_dim=head_dim,
                                     num_groups=1, conv_width=4,
                                     chunk_size=chunk, expand=2),
                       param_dtype="float32", compute_dtype="float32")


def naive_recurrence(xh, dt, A, Bm, Cm):
    """y_t = C_t h_t + ..., h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    b, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    R = H // G
    h = np.zeros((b, H, P, N))
    ys = np.zeros((b, S, H, P))
    for t in range(S):
        for head in range(H):
            g = head // R
            decay = np.exp(dt[:, t, head, None, None] * A[head])
            upd = (dt[:, t, head, None, None]
                   * xh[:, t, head, :, None] * Bm[:, t, g, None, :])
            h[:, head] = h[:, head] * decay + upd
            ys[:, t, head] = np.einsum("bpn,bn->bp", h[:, head],
                                       Cm[:, t, g])
    return ys, h


@pytest.mark.parametrize("S", [8, 16, 19])
def test_chunked_matches_naive_recurrence(S):
    cfg = mk_cfg(chunk=8)
    s = cfg.ssm
    b, H, P, G, N = 2, 4, s.head_dim, 1, s.d_state
    k = KEY
    xh = np.asarray(jax.random.normal(k, (b, S, H, P)))
    dt = np.asarray(jax.nn.softplus(jax.random.normal(
        jax.random.fold_in(k, 1), (b, S, H))))
    A = -np.exp(np.asarray(jax.random.normal(jax.random.fold_in(k, 2), (H,))))
    Bm = np.asarray(jax.random.normal(jax.random.fold_in(k, 3), (b, S, G, N)))
    Cm = np.asarray(jax.random.normal(jax.random.fold_in(k, 4), (b, S, G, N)))

    y, h_fin = M._ssd_chunked(jnp.asarray(xh), jnp.asarray(dt),
                              jnp.asarray(A), jnp.asarray(Bm),
                              jnp.asarray(Cm), cfg, None)
    y_ref, h_ref = naive_recurrence(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_fin), h_ref, atol=1e-4)


def test_full_layer_decode_matches_prefill():
    cfg = mk_cfg(chunk=4)
    p = M.mamba_init(KEY, cfg)
    S = 10
    x = jax.random.normal(KEY, (2, S, cfg.d_model))
    full, _ = M.mamba_apply(p, x, cfg)
    cache = M.init_mamba_cache(cfg, 2, jnp.float32)
    _, cache = M.mamba_apply(p, x[:, : S - 1], cfg, cache=cache)
    step, _ = M.mamba_apply(p, x[:, S - 1:], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(step[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-4)


def test_state_continuation_across_prefill_chunks():
    """Prefilling in two halves must equal one prefill (state carry)."""
    cfg = mk_cfg(chunk=4)
    p = M.mamba_init(KEY, cfg)
    S = 16
    x = jax.random.normal(KEY, (1, S, cfg.d_model))
    full, _ = M.mamba_apply(p, x, cfg)
    cache = M.init_mamba_cache(cfg, 1, jnp.float32)
    y1, cache = M.mamba_apply(p, x[:, : S // 2], cfg, cache=cache)
    y2, _ = M.mamba_apply(p, x[:, S // 2:], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(full[:, : S // 2]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(full[:, S // 2:]),
                               atol=1e-4)
