"""Selection policies: contract + behavioural checks."""

import numpy as np
import pytest

from repro.core import RoundState, Feedback, favor_reward, make_policy

N, K, DIM = 30, 6, 4


def mk_state(seed=0, round_idx=0):
    rng = np.random.default_rng(seed)
    # two well-separated client groups in embedding space
    embeds = np.concatenate([rng.normal(size=(N // 2, DIM)) - 4,
                             rng.normal(size=(N // 2, DIM)) + 4]).astype(
                                 np.float32)
    return RoundState(round_idx, embeds, np.zeros(DIM, np.float32), 0.1)


@pytest.mark.parametrize("name", ["fedavg", "kcenter", "favor", "dqre_sc"])
def test_policy_contract(name):
    kw = {"num_clusters": 4} if name == "dqre_sc" else {}
    pol = make_policy(name, N, K, DIM, seed=0, **kw)
    state = mk_state()
    sel = pol.select(state)
    assert len(sel) == K
    assert len(set(sel.tolist())) == K                 # unique
    assert all(0 <= c < N for c in sel)
    # update must not crash
    pol.update(state, mk_state(1, 1), Feedback(0.5, favor_reward(0.5, 0.8),
                                               sel))


def test_kcenter_spreads_across_groups():
    pol = make_policy("kcenter", N, K, DIM, seed=0)
    sel = pol.select(mk_state())
    groups = (sel >= N // 2).astype(int)
    assert 0 < groups.sum() < K                        # both groups hit


def test_fedavg_uniform_coverage():
    pol = make_policy("fedavg", N, K, DIM, seed=0)
    counts = np.zeros(N)
    for _ in range(200):
        counts[pol.select(mk_state())] += 1
    # no client starved, no client dominating
    assert counts.min() > 0
    assert counts.max() / counts.sum() < 0.10


def test_dqre_sc_uses_all_clusters_under_exploration():
    pol = make_policy("dqre_sc", N, K, DIM, seed=0, num_clusters=2)
    seen = set()
    for r in range(10):
        sel = pol.select(mk_state(seed=r, round_idx=r))
        seen.update((sel >= N // 2).astype(int).tolist())
        pol.update(mk_state(seed=r), mk_state(seed=r + 1),
                   Feedback(0.3, -0.5, sel))
    assert seen == {0, 1}


def test_dqre_sc_nystrom_contract():
    """Approximate Algorithm I path: still a valid unique cohort."""
    pol = make_policy("dqre_sc", N, K, DIM, seed=0, num_clusters=4,
                      approx_method="nystrom", num_landmarks=N // 2)
    state = mk_state()
    sel = pol.select(state)
    assert len(sel) == K and len(set(sel.tolist())) == K
    pol.update(state, mk_state(1, 1), Feedback(0.4, -0.6, sel))


def test_dqre_sc_caches_clustering_per_round():
    """select() and update() see the same embeddings once per round;
    Algorithm I must run once, not twice."""
    pol = make_policy("dqre_sc", N, K, DIM, seed=0, num_clusters=4)
    s0, s1 = mk_state(seed=0, round_idx=0), mk_state(seed=1, round_idx=1)
    pol.select(s0)
    assert pol.cluster_computes == 1
    # update clusters next_state's embeddings — one fresh compute
    pol.update(s0, s1, Feedback(0.4, -0.6, np.arange(K)))
    assert pol.cluster_computes == 2
    # next round's select sees the same embeddings update just clustered
    pol.select(mk_state(seed=1, round_idx=1))
    assert pol.cluster_computes == 2                    # cache hit
    # a genuinely new embedding matrix recomputes
    pol.select(mk_state(seed=2, round_idx=2))
    assert pol.cluster_computes == 3


def test_dqre_sc_auto_k_contract():
    """Eigengap auto-k (paper §3.4): still returns a valid unique cohort."""
    pol = make_policy("dqre_sc", N, K, DIM, seed=0, num_clusters=6,
                      auto_k=True)
    sel = pol.select(mk_state())
    assert len(set(sel.tolist())) == K
    pol.update(mk_state(), mk_state(1, 1), Feedback(0.4, -0.6, sel))


def test_favor_reward_shaping():
    assert favor_reward(0.8, 0.8) == pytest.approx(0.0)
    assert favor_reward(0.9, 0.8) > 0
    assert favor_reward(0.5, 0.8) < 0
    assert favor_reward(0.9, 0.8) < 64 ** 0.1          # bounded
