"""Spectral clustering (Algorithm I) — structural and behavioural tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (affinity_matrix, auto_gamma, eigengap_k, kmeans,
                        normalized_laplacian, spectral_cluster,
                        spectral_embedding)
from repro.core.kmeans import pairwise_sq_dists

KEY = jax.random.PRNGKey(0)


def two_blobs(n=40, sep=8.0, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n // 2, 2)) + [0, 0]
    b = rng.normal(size=(n // 2, 2)) + [sep, sep]
    x = np.concatenate([a, b]).astype(np.float32)
    labels = np.array([0] * (n // 2) + [1] * (n // 2))
    return x, labels


def test_affinity_properties():
    x = jnp.asarray(two_blobs()[0])
    a = affinity_matrix(x, gamma=0.5)
    a = np.asarray(a)
    assert np.allclose(a, a.T, atol=1e-6)          # symmetric
    assert np.all(a >= 0) and np.all(a <= 1)       # RBF range
    assert np.allclose(np.diag(a), 0)              # zero diagonal


def test_auto_gamma_matches_offdiag_median_across_scales():
    """Regression: jnp.median on the NaN-masked distance matrix returned
    NaN and silently collapsed gamma to the 0.5 fallback for every input.
    The median heuristic must track the true off-diagonal median at any
    data scale."""
    base = two_blobs()[0]
    for scale in (1.0, 100.0):
        x = jnp.asarray(base * scale)
        d2 = np.asarray(pairwise_sq_dists(x, x))
        off = ~np.eye(len(d2), dtype=bool)
        true_med = np.median(d2[off])
        expect = 1.0 / (2.0 * true_med)
        got = float(auto_gamma(jnp.asarray(d2 * off)))
        np.testing.assert_allclose(got, expect, rtol=1e-4)
        # and the affinity built with auto-gamma matches the explicit one
        a_auto = np.asarray(affinity_matrix(x))
        a_explicit = np.asarray(affinity_matrix(x, gamma=expect))
        np.testing.assert_allclose(a_auto, a_explicit, atol=1e-5)


def test_laplacian_psd_with_zero_eigenvalue():
    x = jnp.asarray(two_blobs()[0])
    lap = normalized_laplacian(affinity_matrix(x, gamma=0.5))
    evals = np.linalg.eigvalsh(np.asarray(lap))
    assert evals.min() > -1e-5                     # PSD
    assert evals.min() < 1e-3                      # ~0 smallest eigenvalue


def test_spectral_embedding_rows_unit_norm():
    x = jnp.asarray(two_blobs()[0])
    y, _ = spectral_embedding(affinity_matrix(x, gamma=0.5), 2)
    norms = np.linalg.norm(np.asarray(y), axis=1)
    assert np.allclose(norms, 1.0, atol=1e-4)


def test_spectral_cluster_separates_blobs():
    x, labels = two_blobs()
    assign, _, _ = spectral_cluster(KEY, jnp.asarray(x), 2)
    assign = np.asarray(assign)
    # clustering is label-invariant: check purity
    purity = max(np.mean(assign == labels), np.mean(assign == 1 - labels))
    assert purity > 0.95


def test_eigengap_detects_two_clusters():
    x, _ = two_blobs(sep=12.0)
    a = affinity_matrix(jnp.asarray(x), gamma=0.5)
    _, evals = spectral_embedding(a, 2)
    assert int(eigengap_k(evals)) == 2


def test_kmeans_assigns_to_nearest_center():
    x, _ = two_blobs()
    assign, centers = kmeans(KEY, jnp.asarray(x), 2)
    d = np.linalg.norm(x[:, None] - np.asarray(centers)[None], axis=-1)
    np.testing.assert_array_equal(np.asarray(assign), d.argmin(axis=1))


def test_pallas_affinity_agrees_inside_spectral_path():
    x = jnp.asarray(two_blobs()[0])
    a_jnp = affinity_matrix(x, gamma=0.5, use_pallas=False)
    a_pal = affinity_matrix(x, gamma=0.5, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a_jnp), np.asarray(a_pal),
                               atol=5e-5)
