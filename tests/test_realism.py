"""Chaos suite for the client-realism layer (fed/realism.py).

Deterministic fault injection: every scenario here is a fixed-seed
replay, so availability dips, stragglers, mid-round dropouts and churn
are asserted bit-for-bit — no flaky sleeps, no host randomness.
"""

import dataclasses

import numpy as np
import pytest

from repro.cohort import CohortConfig
from repro.fed import (ClientTrace, FederatedRunner, RoundSpec, RunnerConfig,
                       SimClock, TraceSpec, blended_reward, fedavg_aggregate,
                       filter_survivors, serving_state_dim)
from repro.core.selection import favor_reward
from repro.launch.serve import CohortServer

# tiny-but-real FL config for the runner-level tests: big enough that
# accuracy moves, small enough to keep the suite fast
TINY = dict(dataset="mnist", num_clients=10, clients_per_round=4,
            sigma=0.5, local_steps=2, batch_size=8, train_size=512,
            eval_size=128, policy="fedavg", seed=0)

# a trace whose failure modes are all switched off: realism plumbing
# active (SimClock, outcomes recorded) but every selected client
# completes — the golden-regression control
BENIGN = TraceSpec(availability="none", dropout_hazard=0.0,
                   tiers=(1.0,), latency_jitter=0.0)

CHAOS = TraceSpec(availability="diurnal", day_period_s=60.0,
                  tiers=(1.0, 6.0), base_latency_s=1.0,
                  dropout_hazard=0.1, p_join=0.3, p_leave=0.1)


# -- SimClock ------------------------------------------------------------

def test_sim_clock_monotone_and_injectable():
    clk = SimClock()
    assert clk.now() == 0.0 and clk() == 0.0     # callable: perf_counter API
    assert clk.advance(2.5) == 2.5
    assert clk.advance(0.0) == 2.5
    with pytest.raises(ValueError):
        clk.advance(-1.0)


# -- availability --------------------------------------------------------

def test_availability_always_a_probability():
    # amplitude + floor deliberately exceed 1: the curve must clip
    spec = TraceSpec(availability="diurnal", avail_floor=0.5,
                     avail_amplitude=3.0)
    trace = ClientTrace(32, spec, seed=1)
    for t in (0.0, 17.3, 120.0, 1e6):
        a = trace.availability(t)
        assert a.shape == (32,)
        assert np.all(a >= 0.0) and np.all(a <= 1.0)
    # "none" model: everyone always up
    assert np.all(ClientTrace(8, BENIGN, seed=0).availability(5.0) == 1.0)


def test_diurnal_phase_staggers_clients():
    spec = TraceSpec(availability="diurnal", day_period_s=100.0,
                     avail_floor=0.0, avail_amplitude=1.0,
                     phase_assign=(0.0, 0.5))
    trace = ClientTrace(2, spec, seed=0)
    a = trace.availability(25.0)         # client 0 at peak, client 1 at trough
    assert a[0] == pytest.approx(1.0) and a[1] == pytest.approx(0.0, abs=1e-9)


# -- churn ---------------------------------------------------------------

def test_membership_round0_everyone_and_churn_step_delta():
    trace = ClientTrace(40, CHAOS, seed=3)
    assert trace.membership(0).all()
    j0, l0 = trace.churn_step(0)
    assert len(j0) == 0 and len(l0) == 0
    for r in range(1, 6):
        prev, cur = trace.membership(r - 1), trace.membership(r)
        joined, left = trace.churn_step(r)
        # the delta stream IS the membership diff
        np.testing.assert_array_equal(joined, np.flatnonzero(~prev & cur))
        np.testing.assert_array_equal(left, np.flatnonzero(prev & ~cur))
        assert not np.intersect1d(joined, left).size
    # lazily-built history is pure in (seed, spec, round): re-query agrees
    np.testing.assert_array_equal(trace.membership(3),
                                  ClientTrace(40, CHAOS, seed=3).membership(3))


# -- the simulated round -------------------------------------------------

def test_outcome_partitions_selected():
    trace = ClientTrace(64, CHAOS, seed=7)
    sel = np.arange(0, 64, 3)
    out = trace.simulate_round(2, 30.0, sel, RoundSpec(deadline_s=3.0))
    merged = np.sort(np.concatenate([out.completed, out.dropped]))
    np.testing.assert_array_equal(merged, np.sort(sel))
    assert not np.intersect1d(out.completed, out.dropped).size
    assert sum(out.reasons.values()) == len(out.dropped)
    assert 0.0 <= out.attainment <= 1.0
    assert out.latencies_s.shape == (len(sel),)


def test_deadline_drops_slow_tier_and_server_waits_full_deadline():
    # clients 0-4 fast (stretch 1), 5-7 slow (stretch 50): with
    # deadline 5 the slow tier always misses and the server eats the
    # whole deadline as the round's wall time
    spec = TraceSpec(tiers=(1.0, 50.0), tier_assign=(0,) * 5 + (1,) * 3,
                     base_latency_s=1.0, latency_jitter=0.0)
    trace = ClientTrace(8, spec, seed=0)
    out = trace.simulate_round(0, 0.0, np.arange(8), RoundSpec(deadline_s=5.0))
    np.testing.assert_array_equal(out.completed, [0, 1, 2, 3, 4])
    np.testing.assert_array_equal(out.dropped, [5, 6, 7])
    assert out.reasons == {"unavailable": 0, "deadline": 3, "dropout": 0}
    assert out.elapsed_s == pytest.approx(5.0)
    # no deadline: everyone completes, the slow tier sets the wall time
    out2 = trace.simulate_round(0, 0.0, np.arange(8), RoundSpec())
    assert len(out2.completed) == 8 and out2.elapsed_s == pytest.approx(50.0)
    # the slow responders are flagged stragglers relative to the median
    np.testing.assert_array_equal(out2.straggler_ids, [5, 6, 7])


def test_dropout_hazard_zero_vs_overwhelming():
    calm = ClientTrace(30, TraceSpec(dropout_hazard=0.0), seed=0)
    out = calm.simulate_round(0, 0.0, np.arange(30), RoundSpec())
    assert len(out.completed) == 30 and len(out.dropped) == 0
    storm = ClientTrace(30, TraceSpec(dropout_hazard=50.0), seed=0)
    out = storm.simulate_round(0, 0.0, np.arange(30), RoundSpec())
    assert out.reasons["dropout"] == len(out.dropped) > 25
    # a dropout disconnects partway through: wall time stays below the
    # slowest survivor-or-dropout latency bound
    assert out.elapsed_s <= float(out.latencies_s.max()) + 1e-9


def test_outcomes_independent_of_selection_order():
    """Draws are full (N,) vectors indexed by the cohort, so a client's
    fate must not depend on where in the cohort it sits."""
    trace = ClientTrace(32, CHAOS, seed=11)
    spec = RoundSpec(deadline_s=4.0)
    a = trace.simulate_round(1, 10.0, np.array([3, 9, 21, 30]), spec)
    b = trace.simulate_round(1, 10.0, np.array([30, 21, 9, 3]), spec)
    assert set(a.completed.tolist()) == set(b.completed.tolist())
    assert set(a.dropped.tolist()) == set(b.dropped.tolist())


def test_trace_replay_bit_identical():
    t1 = ClientTrace(48, CHAOS, seed=42)
    t2 = ClientTrace(48, CHAOS, seed=42)
    other = ClientTrace(48, CHAOS, seed=43)
    sel = np.arange(0, 48, 2)
    spec = RoundSpec(deadline_s=3.0)
    diverged = False
    for r in range(5):
        o1 = t1.simulate_round(r, r * 7.0, sel, spec)
        o2 = t2.simulate_round(r, r * 7.0, sel, spec)
        np.testing.assert_array_equal(o1.completed, o2.completed)
        np.testing.assert_array_equal(o1.dropped, o2.dropped)
        np.testing.assert_array_equal(o1.latencies_s, o2.latencies_s)
        assert o1.elapsed_s == o2.elapsed_s and o1.reasons == o2.reasons
        o3 = other.simulate_round(r, r * 7.0, sel, spec)
        diverged |= (o3.reasons != o1.reasons
                     or not np.array_equal(o3.latencies_s, o1.latencies_s))
    assert diverged                       # the seed actually matters


def test_trace_validation_errors():
    with pytest.raises(ValueError, match="num_clients"):
        ClientTrace(0)
    with pytest.raises(ValueError, match="availability"):
        ClientTrace(4, TraceSpec(availability="weekly"))
    with pytest.raises(ValueError, match="tiers"):
        ClientTrace(4, TraceSpec(tiers=(1.0, -2.0)))
    with pytest.raises(ValueError, match="tier_assign"):
        ClientTrace(4, TraceSpec(tiers=(1.0,), tier_assign=(0, 0, 1, 0)))
    with pytest.raises(ValueError, match="one entry per"):
        ClientTrace(4, TraceSpec(phase_assign=(0.1, 0.2)))
    with pytest.raises(ValueError):
        ClientTrace(4).membership(-1)


# -- aggregation safety --------------------------------------------------

def test_dropped_clients_cannot_poison_aggregation():
    """A mid-round dropout's partial work — even NaN — must contribute
    exactly nothing: survivors are sliced out BEFORE FedAvg and the
    weights renormalize over them."""
    k, shape = 5, (3, 2)
    rng = np.random.default_rng(0)
    stacked = {"w": rng.normal(size=(k, *shape)).astype(np.float32)}
    weights = np.array([1.0, 2.0, 3.0, 4.0, 5.0], np.float32)
    mask = np.array([True, False, True, False, True])
    stacked["w"][~mask] = np.nan          # poisoned partial updates
    fp, fw = filter_survivors(stacked, weights, mask)
    assert fp["w"].shape == (3, *shape) and len(fw) == 3
    agg = np.asarray(fedavg_aggregate(fp, fw)["w"])
    assert np.isfinite(agg).all()
    expect = np.average(stacked["w"][mask], axis=0, weights=weights[mask])
    np.testing.assert_allclose(agg, expect, rtol=1e-6)
    # all-survivors passthrough; zero-survivor rounds must be skipped
    same_p, same_w = filter_survivors(stacked, weights, np.ones(k, bool))
    assert same_p is stacked and same_w is weights
    with pytest.raises(ValueError, match="no survivors"):
        filter_survivors(stacked, weights, np.zeros(k, bool))


def test_blended_reward_limits():
    # blend=0 is exactly the paper's shaping
    assert blended_reward(0.7, 0.85, 0.5, blend=0.0) == pytest.approx(
        favor_reward(0.7, 0.85))
    # full attainment adds nothing; zero attainment costs the blend share
    assert blended_reward(0.85, 0.85, 1.0, blend=0.5) == pytest.approx(0.0)
    assert blended_reward(0.85, 0.85, 0.0, blend=0.5) == pytest.approx(-0.5)
    with pytest.raises(ValueError, match="blend"):
        blended_reward(0.5, 0.85, 1.0, blend=1.5)


# -- FederatedRunner integration -----------------------------------------

def test_golden_regression_benign_trace_matches_ideal_runner():
    """deadline=None + no failure modes: the realism path must reproduce
    the ideal simulation bit-for-bit (accuracy, cohorts, rewards) —
    fault injection off is the seed behavior."""
    ideal = FederatedRunner(RunnerConfig(**TINY))
    real = FederatedRunner(RunnerConfig(**TINY, realism=BENIGN))
    h1, h2 = ideal.run(2), real.run(2)
    for a, b in zip(h1, h2):
        assert a.accuracy == b.accuracy and a.loss == b.loss
        assert a.reward == b.reward
        np.testing.assert_array_equal(a.selected, b.selected)
        # the whole cohort completed; nothing dropped
        assert b.num_completed == len(b.selected) and b.num_dropped == 0
    # realism timings are simulated: each round costs the cohort's max
    # latency (exactly base_latency_s with jitter 0) on the SimClock
    assert real.sim_clock is not None
    for res in h2:
        assert res.sim_seconds == pytest.approx(BENIGN.base_latency_s)
        assert res.outcome is not None and res.outcome.elapsed_s > 0
    assert real.sim_clock.now() == pytest.approx(2 * BENIGN.base_latency_s)


def test_runner_replay_bit_identical_under_chaos():
    """The headline determinism contract: same (seed, trace, spec) ⇒
    the full chaotic history replays exactly."""
    cfg = RunnerConfig(**TINY, realism=CHAOS,
                       round_spec=RoundSpec(deadline_s=3.0,
                                            reward_blend=0.5))
    h1 = FederatedRunner(cfg).run(3)
    h2 = FederatedRunner(cfg).run(3)
    assert any(r.num_dropped for r in h1)         # chaos actually bites
    for a, b in zip(h1, h2):
        assert a.accuracy == b.accuracy and a.reward == b.reward
        np.testing.assert_array_equal(a.selected, b.selected)
        np.testing.assert_array_equal(a.outcome.completed,
                                      b.outcome.completed)
        assert a.num_completed == b.num_completed
        assert a.num_dropped == b.num_dropped
        assert a.num_stragglers == b.num_stragglers
        assert a.sim_seconds == b.sim_seconds
        assert a.timings == b.timings             # SimClock: simulated phases
        assert a.seconds == pytest.approx(sum(a.timings.values()))


def test_attach_trace_guards():
    runner = FederatedRunner(RunnerConfig(**TINY))
    with pytest.raises(ValueError, match="clients"):
        runner.attach_trace(ClientTrace(99, BENIGN, seed=0))
    runner.run(1)
    with pytest.raises(RuntimeError, match="already ran"):
        runner.attach_trace(ClientTrace(TINY["num_clients"], BENIGN, seed=0))


# -- serving: state_features="system" round trip -------------------------

def test_system_state_round_trips_through_observe_round():
    """A realism RoundOutcome fed to CohortServer.observe_round must (a)
    blend the reward with deadline attainment, (b) move the per-cluster
    availability/latency EMAs, and (c) produce 7k+1 system states that
    the DQN accepts end to end."""
    n, d, k = 60, 6, 3
    rng = np.random.default_rng(0)
    x = np.concatenate([rng.normal(size=(n // k, d)) + 8.0 * c
                        for c in range(k)]).astype(np.float32)
    srv = CohortServer(n, d, seed=0, policy="dqn",
                       config=CohortConfig(num_clusters=k),
                       state_features="system",
                       dqn_overrides={"hidden": (16,), "buffer_size": 64,
                                      "batch_size": 8})
    assert srv.policy.agent.cfg.state_dim == serving_state_dim(k, "system")
    assert serving_state_dim(k, "system") == 7 * k + 1
    srv.update_embeddings(np.arange(n), x)

    trace = ClientTrace(n, TraceSpec(tiers=(1.0, 40.0),
                                     tier_assign=tuple([0] * (n // 2)
                                                       + [1] * (n // 2)),
                                     latency_jitter=0.0), seed=0)
    spec = RoundSpec(deadline_s=5.0)
    avail0 = srv._avail_ema.copy()
    for r in range(3):
        ids, _ = srv.select_cohort(8)
        out = trace.simulate_round(r, 0.0, ids, spec)
        reward = srv.observe_round(0.5, timings={"train": 0.1}, outcome=out)
        assert reward == pytest.approx(
            blended_reward(0.5, srv.target_accuracy, out.attainment))
    # the slow half always misses the 5s deadline, so at least one
    # cluster's completion-rate EMA fell from its optimistic start and
    # every served cluster accumulated a latency estimate
    assert (srv._avail_ema <= avail0 + 1e-12).all()
    assert (srv._avail_ema < avail0).any()
    assert (srv._latency_ema_s > 0).any()
    assert srv.stats()["rounds_observed"] == 3
    # without an outcome the reward falls back to the paper's shaping
    ids, _ = srv.select_cohort(8)
    assert srv.observe_round(0.6) == pytest.approx(
        favor_reward(0.6, srv.target_accuracy))


def test_churn_delta_feeds_update_embeddings():
    """churn_step's (joined, left) ids are a valid update_embeddings
    delta stream: versions bump once per churn event batch and the
    served table reflects the latest rows."""
    n, d = 20, 4
    srv = CohortServer(n, d, seed=0, config=CohortConfig(num_clusters=2))
    srv.update_embeddings(np.arange(n), np.ones((n, d), np.float32))
    trace = ClientTrace(n, TraceSpec(p_join=0.5, p_leave=0.4), seed=5)
    v = srv.version
    for r in range(1, 6):
        joined, left = trace.churn_step(r)
        delta = np.concatenate([joined, left])
        if not len(delta):
            continue
        rows = np.zeros((len(delta), d), np.float32)
        rows[: len(joined)] = float(r)    # joins bring fresh embeddings
        srv.update_embeddings(delta, rows)    # leaves tombstone to zeros
        assert srv.version == v + 1
        v = srv.version
        table = srv.embeds
        if len(left):
            np.testing.assert_array_equal(table[left], 0.0)
        if len(joined):
            np.testing.assert_array_equal(table[joined], float(r))
