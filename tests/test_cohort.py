"""Cohort engine: lifecycle, determinism, landmark quality, warm starts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cohort import (CohortConfig, CohortEngine, select_landmarks,
                          subspace_topk)
from repro.core import spectral_cluster
from repro.core.selection import DQREScSelection, RoundState

KEY = jax.random.PRNGKey(0)


def blobs(n=400, k=4, sep=8.0, d=8, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * sep
    labels = rng.integers(0, k, n)
    x = (centers[labels] + rng.normal(size=(n, d))).astype(np.float32)
    return x, labels


def skewed_blobs(seed=0, d=8, sep=10.0):
    """Non-IID fixture: a head cluster with 75 % of the clients + 5 tails."""
    rng = np.random.default_rng(seed)
    sizes = [450, 30, 30, 30, 30, 30]
    centers = rng.normal(size=(len(sizes), d)) * sep
    labels = np.repeat(np.arange(len(sizes)), sizes)
    x = (centers[labels]
         + rng.normal(size=(len(labels), d))).astype(np.float32)
    return x, labels


def purity(assign, labels):
    return sum(np.bincount(labels[assign == c]).max()
               for c in np.unique(assign)) / len(labels)


def same_partition(a, b):
    """Label-permutation-invariant equality of two clusterings."""
    pa = a[:, None] == a[None, :]
    pb = b[:, None] == b[None, :]
    return bool(np.all(pa == pb))


# -- lifecycle ----------------------------------------------------------
def test_engine_dense_clusters_blobs():
    x, labels = blobs()
    res = CohortEngine(CohortConfig(num_clusters=4), seed=0).select(x)
    assert res.method == "dense" and res.source == "cold"
    assert purity(res.assign, labels) >= 0.95
    assert res.embedding.shape == (len(x), 4)


def test_engine_auto_method_resolution():
    small, _ = blobs(n=128)
    big, _ = blobs(n=2100)
    eng = CohortEngine(CohortConfig(num_clusters=4), seed=0)
    assert eng.select(small).method == "dense"
    # above the dense cutoff: always the jitted mesh path (1-way mesh on
    # a single device)
    assert eng.select(big).method == "sharded"


def test_engine_exact_cache_hit():
    x, _ = blobs()
    eng = CohortEngine(CohortConfig(num_clusters=4), seed=0)
    r1 = eng.select(x)
    r2 = eng.select(x)
    assert r2.source == "cache"
    assert np.array_equal(r1.assign, r2.assign)
    assert eng.stats["solves"] == 1 and eng.stats["cache_hits"] == 1


def test_engine_auto_k_caps_clusters():
    x, _ = blobs(k=2, sep=12.0)
    eng = CohortEngine(CohortConfig(num_clusters=6, auto_k=True), seed=0)
    res = eng.select(x)
    assert 2 <= res.k <= 6
    assert res.embedding.shape[1] == res.k
    assert res.assign.max() < res.k


def test_engine_rejects_bad_knobs():
    with pytest.raises(ValueError, match="method"):
        CohortConfig(method="magic")
    with pytest.raises(ValueError, match="strategy"):
        CohortConfig(landmarks="psychic")
    with pytest.raises(ValueError, match="solver"):
        CohortConfig(solver="cg")
    with pytest.raises(ValueError, match="strategy"):
        select_landmarks(KEY, jnp.zeros((8, 2)), 4, "psychic")


# -- determinism (satellite: explicit PRNG threading) -------------------
def test_engine_cold_solve_bit_identical_regardless_of_history():
    """Regression: PR 1 derived landmark seeds from a mutating key
    stream, so re-clustering the same embeddings after any other solve
    gave a different cohort.  Cold solves must be pure in (seed, embeds)."""
    x, _ = blobs(seed=0)
    y, _ = blobs(seed=7, sep=3.0)
    cfg = CohortConfig(num_clusters=4, method="nystrom", num_landmarks=64,
                       warm_start=False)
    eng = CohortEngine(cfg, seed=0)
    a1 = eng.select(x).assign.copy()
    eng.select(y)                               # unrelated solve between
    a2 = eng.select(x).assign
    assert np.array_equal(a1, a2)
    # and across engine instances with the same seed
    a3 = CohortEngine(cfg, seed=0).select(x).assign
    assert np.array_equal(a1, a3)


def test_spectral_cluster_nystrom_explicit_landmark_key():
    x = jnp.asarray(blobs(n=160)[0])
    lm = jax.random.PRNGKey(42)
    a1, y1, _ = spectral_cluster(KEY, x, 4, method="nystrom",
                                 num_landmarks=32, landmark_key=lm)
    a2, y2, _ = spectral_cluster(KEY, x, 4, method="nystrom",
                                 num_landmarks=32, landmark_key=lm)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    with pytest.raises(ValueError, match="landmark_key"):
        spectral_cluster(KEY, x, 4, landmark_key=lm)


def test_dqre_sc_policy_select_deterministic():
    x, _ = blobs(n=64, k=2)
    state = RoundState(0, x, np.zeros(8, np.float32), 0.1)
    sels = [DQREScSelection(64, 8, 8, seed=3, num_clusters=4,
                            approx_method="nystrom",
                            num_landmarks=16).select(state)
            for _ in range(2)]
    np.testing.assert_array_equal(sels[0], sels[1])


# -- landmark quality (acceptance: >= uniform purity on skewed data) ----
def test_landmark_strategies_beat_uniform_on_skewed_fixture():
    seeds = range(5)

    def mean_purity(strategy):
        ps = []
        for seed in seeds:
            x, labels = skewed_blobs(seed=seed)
            eng = CohortEngine(
                CohortConfig(num_clusters=6, method="nystrom",
                             num_landmarks=18, landmarks=strategy,
                             warm_start=False), seed=seed)
            ps.append(purity(eng.select(x).assign, labels))
        return float(np.mean(ps))

    uni = mean_purity("uniform")
    assert mean_purity("kmeans++") >= uni
    assert mean_purity("leverage") >= uni


def test_landmark_strategies_return_valid_unique_indices():
    x = jnp.asarray(skewed_blobs()[0])
    for strategy in ("uniform", "kmeans++", "leverage"):
        idx = np.asarray(select_landmarks(KEY, x, 24, strategy))
        assert idx.shape == (24,)
        assert len(np.unique(idx)) == 24
        assert idx.min() >= 0 and idx.max() < len(x)
        idx2 = np.asarray(select_landmarks(KEY, x, 24, strategy))
        np.testing.assert_array_equal(idx, idx2)    # pure in the key


# -- blocked eigensolver ------------------------------------------------
def test_subspace_topk_matches_eigh():
    rng = np.random.default_rng(0)
    b = rng.normal(size=(96, 96)).astype(np.float32)
    w = jnp.asarray(b @ b.T / 96)
    ref = np.linalg.eigh(np.asarray(w))
    evals, evecs = subspace_topk(w, 5, iters=80, key=KEY, block_rows=32)
    np.testing.assert_allclose(np.asarray(evals), ref[0][::-1][:5],
                               rtol=1e-3, atol=1e-4)
    # eigenvectors match up to sign: compare projectors
    p_ref = ref[1][:, ::-1][:, :5] @ ref[1][:, ::-1][:, :5].T
    v = np.asarray(evecs)
    np.testing.assert_allclose(v @ v.T, p_ref, atol=1e-2)


def test_subspace_topk_warm_start_converges_fast():
    rng = np.random.default_rng(1)
    b = rng.normal(size=(64, 64)).astype(np.float32)
    w = jnp.asarray(b @ b.T / 64)
    _, q = subspace_topk(w, 4, iters=80, key=KEY)
    # perturb the operator slightly, re-enter from the converged basis
    w2 = w + 1e-3 * jnp.asarray(np.diag(rng.normal(size=64))
                                .astype(np.float32))
    w2 = 0.5 * (w2 + w2.T)
    warm_evals, _ = subspace_topk(w2, 4, iters=3, q0=q)
    ref = np.linalg.eigh(np.asarray(w2))[0][::-1][:4]
    np.testing.assert_allclose(np.asarray(warm_evals), ref, rtol=1e-3,
                               atol=1e-4)


# -- incremental re-clustering (warm starts) ----------------------------
def _warm_cfg(**kw):
    base = dict(num_clusters=4, method="nystrom", num_landmarks=64,
                solver="subspace", drift_threshold=0.1)
    base.update(kw)
    return CohortConfig(**base)


def test_warm_start_equals_cold_start_after_convergence():
    """A drift-gated warm solve must reproduce the cold solve on the
    same (slightly drifted) embeddings: same partition, same spectrum."""
    x, _ = blobs()
    rng = np.random.default_rng(5)
    x2 = x + 0.01 * rng.normal(size=x.shape).astype(np.float32)

    warm_eng = CohortEngine(_warm_cfg(), seed=0)
    warm_eng.select(x)                                  # converge cold
    r_warm = warm_eng.select(x2)
    assert r_warm.source == "warm"
    assert warm_eng.stats["warm_starts"] == 1

    r_cold = CohortEngine(_warm_cfg(), seed=0).select(x2)
    assert r_cold.source == "cold"
    assert same_partition(r_warm.assign, r_cold.assign)
    np.testing.assert_allclose(r_warm.evals, r_cold.evals, atol=1e-2)


def test_explicit_key_bypasses_fingerprint_cache():
    """select(x, key=K) asks for a solve under K, not a cached replay."""
    x, _ = blobs()
    eng = CohortEngine(CohortConfig(num_clusters=4, method="nystrom",
                                    num_landmarks=64, warm_start=False),
                       seed=0)
    eng.select(x)
    r2 = eng.select(x, key=jax.random.PRNGKey(123))
    assert r2.source == "cold"                      # not "cache"
    # the probe really solved, but it is serving-invisible: persistent
    # counters (solves / cold_starts) only track the default stream
    assert eng.stats["solves"] == 1 and eng.stats["cache_hits"] == 0
    assert eng.stats["probes"] == 1
    assert eng.stats["cold_starts"] == 1


def test_explicit_key_probe_leaves_engine_state_untouched():
    """A one-off keyed probe must not poison the default stream's cache
    or warm-start state: the next default select must equal a fresh
    engine's result, and probe state must not be persisted."""
    x, _ = blobs()
    cfg = CohortConfig(num_clusters=4, method="nystrom", num_landmarks=64,
                       warm_start=False)
    a_ref = CohortEngine(cfg, seed=0).select(x).assign
    eng = CohortEngine(cfg, seed=0)
    eng.select(x, key=jax.random.PRNGKey(999))      # probe first
    assert eng.state.fingerprint is None            # nothing persisted
    np.testing.assert_array_equal(eng.select(x).assign, a_ref)


def test_explicit_key_probe_never_warm_starts():
    """Probes must be fully determined by their key: even with warm
    state available, a keyed select re-samples landmarks under that key
    instead of silently replaying the persisted ones."""
    x, _ = blobs()
    eng = CohortEngine(_warm_cfg(), seed=0)
    eng.select(x)                                   # persist warm state
    r1 = eng.select(x, key=jax.random.PRNGKey(1))
    r2 = eng.select(x, key=jax.random.PRNGKey(2))
    assert r1.source == "cold" and r2.source == "cold"
    assert not np.array_equal(r1.embedding, r2.embedding)


def test_gap_history_is_bounded_in_long_running_engines():
    """The autotuner only reads the last two eigengaps; a server calling
    select for months must not grow the history unboundedly."""
    from repro.cohort.engine import _GAP_HIST_MAX

    eng = CohortEngine(CohortConfig(num_clusters=4, num_landmarks="auto"),
                       seed=0)
    evals = np.linspace(0.0, 1.0, 6)
    for _ in range(10 * _GAP_HIST_MAX):
        eng._update_auto_m(n=1000, k=4, drift=0.01, evals=evals)
    assert len(eng._gap_hist) == _GAP_HIST_MAX


def test_cache_hit_returns_copies_not_aliases():
    x, _ = blobs()
    eng = CohortEngine(CohortConfig(num_clusters=4), seed=0)
    eng.select(x)
    r_cached = eng.select(x)
    assert r_cached.source == "cache"
    assert r_cached.assign is not eng.state.result.assign
    r_cached.assign[:] = 0                          # caller mutates copy
    assert len(np.unique(eng.select(x).assign)) > 1


def test_policy_rejects_mismatched_cohort_config():
    with pytest.raises(ValueError, match="num_clusters"):
        DQREScSelection(64, 8, 8, num_clusters=4,
                        cohort_config=CohortConfig(num_clusters=8))
    # overlapping constructor args must not be silently discarded
    with pytest.raises(ValueError, match="cohort_config"):
        DQREScSelection(64, 8, 8, num_clusters=4,
                        approx_method="nystrom", num_landmarks=16,
                        cohort_config=CohortConfig(num_clusters=4))


def test_auto_k_subspace_sees_full_eigengap_window():
    """Regression: subspace solvers returned only k eigenvalues, so the
    eigengap never saw the lambda_k/lambda_{k+1} gap and auto_k was
    silently capped at k-1.  The engine now solves k+1 wide under
    auto_k, so both solvers see the same gap window and must agree."""
    x, _ = blobs(n=240, k=4, sep=12.0)

    def run(solver):
        eng = CohortEngine(
            CohortConfig(num_clusters=4, method="nystrom",
                         num_landmarks=64, solver=solver, auto_k=True,
                         warm_start=False), seed=0)
        return eng.select(x)

    r_sub, r_eigh = run("subspace"), run("eigh")
    assert len(r_sub.evals) == 5          # k+1, not k
    assert r_sub.k == r_eigh.k            # same eigengap decision
    assert r_sub.embedding.shape[1] == r_sub.k


def test_cumulative_drift_eventually_forces_cold_refresh():
    """Drift is measured against the last COLD baseline, so steady
    sub-threshold per-round drift accumulates and must trigger a
    landmark/bandwidth refresh instead of warm-starting forever."""
    x, _ = blobs()
    eng = CohortEngine(_warm_cfg(), seed=0)
    eng.select(x)
    rng = np.random.default_rng(11)
    step = rng.normal(size=x.shape).astype(np.float32)
    step *= 0.02 * np.linalg.norm(x) / np.linalg.norm(step)
    sources = []
    for t in range(1, 30):
        sources.append(eng.select(x + t * step).source)
        if sources[-1] == "cold":
            break
    assert "warm" in sources                        # warm path exercised
    assert sources[-1] == "cold"                    # ...but not forever


def test_large_drift_forces_cold_start():
    x, _ = blobs(seed=0)
    y, _ = blobs(seed=9, sep=3.0)
    eng = CohortEngine(_warm_cfg(), seed=0)
    eng.select(x)
    res = eng.select(y)
    assert res.source == "cold" and res.drift > 0.1
    assert eng.stats["warm_starts"] == 0


def test_warm_start_disabled_by_config():
    x, _ = blobs()
    rng = np.random.default_rng(5)
    x2 = x + 0.001 * rng.normal(size=x.shape).astype(np.float32)
    eng = CohortEngine(_warm_cfg(warm_start=False), seed=0)
    eng.select(x)
    assert eng.select(x2).source == "cold"


def test_engine_reset_drops_state():
    x, _ = blobs()
    eng = CohortEngine(_warm_cfg(), seed=0)
    eng.select(x)
    eng.reset()
    assert eng.state.fingerprint is None
    assert eng.select(x).source == "cold"      # no cache hit after reset


# -- policy + runner integration ---------------------------------------
def test_policy_cluster_computes_tracks_engine_solves():
    x, _ = blobs(n=64, k=2)
    pol = DQREScSelection(64, 8, 8, seed=0, num_clusters=4)
    state = RoundState(0, x, np.zeros(8, np.float32), 0.1)
    pol.select(state)
    assert pol.cluster_computes == 1
    pol.select(state)
    assert pol.cluster_computes == 1           # engine cache hit
    assert pol.engine.stats["cache_hits"] == 1


def test_runner_config_threads_cohort_knobs():
    from repro.fed import RunnerConfig
    from repro.fed.rounds import FederatedRunner
    cfg = RunnerConfig(num_clients=12, clients_per_round=4,
                       train_size=256, eval_size=64, policy="dqre_sc",
                       num_clusters=3, approx_method="nystrom",
                       num_landmarks=8, landmarks="kmeans++",
                       warm_start=False)
    runner = FederatedRunner(cfg)
    eng_cfg = runner.policy.engine.config
    assert eng_cfg.method == "nystrom"
    assert eng_cfg.landmarks == "kmeans++"
    assert eng_cfg.num_landmarks == 8
    assert eng_cfg.warm_start is False


# -- landmark-count autotuning (num_landmarks="auto") -------------------
def test_config_rejects_bogus_num_landmarks():
    with pytest.raises(ValueError, match="num_landmarks"):
        CohortConfig(num_landmarks="bogus")
    with pytest.raises(ValueError, match="num_landmarks"):
        CohortConfig(num_landmarks=-4)


def test_auto_landmarks_keeps_base_on_separated_blobs():
    """Strong eigengap -> the static default max(8k, 64) is enough; the
    autotuner must not inflate m (and the result stays valid)."""
    from repro.core.spectral import default_num_landmarks
    x, labels = blobs()
    eng = CohortEngine(CohortConfig(num_clusters=4, method="nystrom",
                                    num_landmarks="auto"), seed=0)
    res = eng.select(x)
    assert res.assign.shape == (len(x),)
    assert purity(res.assign, labels) >= 0.9
    assert eng.stats["auto_m"] == default_num_landmarks(len(x), 4)
    # the widened (k+1) solve is an internal detail: the published
    # embedding keeps the configured k columns
    assert res.embedding.shape[1] == 4


def test_auto_landmarks_grows_on_weak_eigengap():
    """Structureless embeddings show no k-cluster gap -> the autotuner
    doubles m (bounded) on consecutive cold solves."""
    from repro.core.spectral import default_num_landmarks
    rng = np.random.default_rng(0)
    base = default_num_landmarks(400, 4)
    eng = CohortEngine(CohortConfig(num_clusters=4, method="nystrom",
                                    num_landmarks="auto",
                                    warm_start=False), seed=0)
    for _ in range(2):
        eng.select(rng.normal(size=(400, 8)).astype(np.float32))
    assert eng.stats["auto_m"] > base
    assert eng.stats["auto_m"] <= 8 * base


def test_auto_landmarks_stable_under_warm_starts():
    """Warm solves must not retune m (the warm-start check requires the
    persisted landmark set to keep its size)."""
    x, _ = blobs()
    rng = np.random.default_rng(3)
    eng = CohortEngine(CohortConfig(num_clusters=4, method="nystrom",
                                    num_landmarks="auto",
                                    drift_threshold=0.1), seed=0)
    eng.select(x)
    m0 = eng.stats["auto_m"]
    r = eng.select(x + 0.01 * rng.normal(size=x.shape).astype(np.float32))
    assert r.source == "warm"
    assert eng.stats["auto_m"] == m0


def test_auto_landmarks_bases_m_on_configured_k():
    """Regression: the widened (k+1) solve must base m on the configured
    k, not the solve width — at num_clusters=9 the k+1 base made the
    first solve use 80 landmarks while auto_m recorded 72, so the
    warm-start size check could never match and every solve ran cold."""
    from repro.core.spectral import default_num_landmarks
    x, _ = blobs(n=300, k=8)
    rng = np.random.default_rng(5)
    eng = CohortEngine(CohortConfig(num_clusters=9, method="nystrom",
                                    num_landmarks="auto",
                                    drift_threshold=0.1), seed=0)
    eng.select(x)
    assert len(eng.state.landmark_idx) == default_num_landmarks(300, 9)
    # invariant: auto_m is always the m the NEXT solve actually uses,
    # even while the weak-gap escalation is doubling it
    for _ in range(3):
        m_next = eng.stats["auto_m"]
        x = x + 0.005 * rng.normal(size=x.shape).astype(np.float32)
        eng.select(x)
        assert len(eng.state.landmark_idx) == m_next
    # once m stops moving (capped or strong gap), warm starts resume
    if eng.stats["auto_m"] == len(eng.state.landmark_idx):
        r = eng.select(
            x + 0.005 * rng.normal(size=x.shape).astype(np.float32))
        assert r.source == "warm"
