"""Per-kernel shape/dtype sweeps + assert_allclose vs the ref.py oracles
(interpret=True executes the kernel bodies in Python on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("n,m,d", [(16, 16, 4), (100, 70, 16), (129, 65, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_dist_sweep(n, m, d, dtype):
    x = jax.random.normal(KEY, (n, d), dtype)
    y = jax.random.normal(jax.random.fold_in(KEY, 1), (m, d), dtype)
    got = ops.pairwise_sq_dists(x, y, block_m=32, block_n=32)
    want = ref.pairwise_sq_dists_ref(x, y)
    atol = 1e-4 if dtype == jnp.float32 else 0.3
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol, rtol=1e-2)


@pytest.mark.parametrize("n", [31, 64, 130])
def test_rbf_affinity_sweep(n):
    x = jax.random.normal(KEY, (n, 8))
    got = ops.rbf_affinity(x, 0.7, block_m=32, block_n=32)
    want = ref.rbf_affinity_ref(x, 0.7)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("n,m,d", [(16, 16, 4), (100, 33, 16), (129, 65, 8)])
def test_rbf_cross_affinity_sweep(n, m, d):
    """Rectangular Nyström cross-affinity block vs the jnp oracle."""
    x = jax.random.normal(KEY, (n, d))
    y = jax.random.normal(jax.random.fold_in(KEY, 1), (m, d))
    got = ops.rbf_cross_affinity(x, y, 0.4, block_m=32, block_n=32)
    want = ref.rbf_cross_affinity_ref(x, y, 0.4)
    assert got.shape == (n, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_rbf_cross_affinity_self_keeps_unit_diagonal():
    """Unlike the square affinity kernel, the cross block has no
    zero-diagonal convention: identical rows give affinity 1."""
    x = jax.random.normal(KEY, (40, 8))
    got = np.asarray(ops.rbf_cross_affinity(x, x, 0.7, block_m=32,
                                            block_n=32))
    np.testing.assert_allclose(np.diag(got), 1.0, atol=1e-5)


@pytest.mark.parametrize("S,H,K,dh", [(33, 4, 4, 16), (64, 8, 2, 32),
                                      (50, 4, 1, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(S, H, K, dh, causal):
    q = jax.random.normal(KEY, (2, S, H, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, S, K, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, S, K, dh))
    got = ops.flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEY, (1, 32, 2, 16), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 32, 2, 16), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 32, 2, 16), dtype)
    got = ops.flash_attention(q, k, v, block_q=16, block_k=16)
    want = ref.attention_ref(q, k, v)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=1e-5 if dtype == jnp.float32 else 0.05)


def test_flash_attention_window():
    S = 48
    q = jax.random.normal(KEY, (1, S, 2, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, S, 2, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, S, 2, 16))
    got = ops.flash_attention(q, k, v, causal=True, window=8,
                              block_q=16, block_k=16)
    want = ref.attention_ref(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("Q,H,P,G,N", [(8, 2, 8, 1, 8), (16, 4, 8, 2, 12),
                                       (32, 8, 16, 1, 16)])
def test_ssd_chunk_sweep(Q, H, P, G, N):
    B, c = 2, 3
    xdt = jax.random.normal(KEY, (B, c, Q, H, P))
    cs = jnp.cumsum(-jnp.abs(jax.random.normal(
        jax.random.fold_in(KEY, 1), (B, c, Q, H))), axis=2)
    Bm = jax.random.normal(jax.random.fold_in(KEY, 2), (B, c, Q, G, N))
    Cm = jax.random.normal(jax.random.fold_in(KEY, 3), (B, c, Q, G, N))
    y, st = ops.ssd_chunk(xdt, cs, Bm, Cm)
    y_r, st_r = ref.ssd_chunk_ref(xdt, cs, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_r), atol=1e-4)


def test_blocked_jnp_attention_matches_flash_kernel():
    """The model's jnp blocked path and the Pallas kernel are twins."""
    from repro.models.attention import blocked_attention
    S = 40
    q = jax.random.normal(KEY, (1, S, 4, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, S, 2, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, S, 2, 16))
    a = blocked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    b = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_flash_attention_ref_oracle_matches_attention_ref():
    """Regression (repro-lint pallas-ref-oracle): the flash kernel's
    same-named oracle exists in ref.py and equals the naive attention."""
    q = jax.random.normal(KEY, (1, 16, 4, 8))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 16, 2, 8))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 16, 2, 8))
    got = ref.flash_attention_ref(q, k, v, causal=True, window=8)
    want = ref.attention_ref(q, k, v, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))
