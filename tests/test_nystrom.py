"""Approximate spectral selection: Nyström landmark path + subspace
eigensolver vs the dense Algorithm I oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (affinity_matrix, eigengap_k,
                        nystrom_spectral_embedding, spectral_cluster,
                        spectral_embedding)

KEY = jax.random.PRNGKey(0)


def blobs(n=160, k=2, sep=8.0, d=4, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * sep
    labels = np.repeat(np.arange(k), n // k)
    x = centers[labels] + rng.normal(size=(len(labels), d))
    return x.astype(np.float32), labels


def purity(assign, labels, k):
    total = sum(np.bincount(labels[assign == c]).max()
                for c in range(k) if (assign == c).any())
    return total / len(labels)


@pytest.mark.parametrize("k", [2, 4])
def test_nystrom_matches_dense_oracle_purity(k):
    """Acceptance: blob purity >= 0.95 with m = N/8 landmarks."""
    x, labels = blobs(n=160, k=k)
    assign, _, _ = spectral_cluster(KEY, jnp.asarray(x), k,
                                    method="nystrom",
                                    num_landmarks=len(x) // 8)
    assert purity(np.asarray(assign), labels, k) >= 0.95


def test_nystrom_embedding_rows_unit_norm_and_spectrum():
    x, _ = blobs()
    y, evals = nystrom_spectral_embedding(KEY, jnp.asarray(x), 2, 20)
    norms = np.linalg.norm(np.asarray(y), axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)
    evals = np.asarray(evals)
    # approximates the L_norm spectrum: near-zero head, bounded by 2
    assert evals[0] < 1e-3
    assert evals.max() <= 2.0 + 1e-4


def test_nystrom_eigengap_detects_two_clusters():
    x, _ = blobs(sep=12.0)
    _, evals = nystrom_spectral_embedding(KEY, jnp.asarray(x), 2, 32,
                                          gamma=0.5)
    assert int(eigengap_k(evals)) == 2


def test_nystrom_evals_close_to_dense():
    """Leading eigenvalues of the approximate L_norm track the exact ones."""
    x, _ = blobs(n=120)
    a = affinity_matrix(jnp.asarray(x), gamma=0.5)
    _, dense_evals = spectral_embedding(a, 2)
    _, nys_evals = nystrom_spectral_embedding(KEY, jnp.asarray(x), 2, 60,
                                              gamma=0.5)
    np.testing.assert_allclose(np.asarray(nys_evals[:2]),
                               np.asarray(dense_evals[:2]), atol=0.1)


def test_subspace_solver_matches_eigh():
    """Orthogonal iteration recovers the same smallest-k eigenpairs."""
    x, labels = blobs(n=120)
    a = affinity_matrix(jnp.asarray(x), gamma=0.5)
    y_exact, ev_exact = spectral_embedding(a, 2, solver="eigh")
    y_sub, ev_sub = spectral_embedding(a, 2, solver="subspace", iters=80)
    np.testing.assert_allclose(np.asarray(ev_sub),
                               np.asarray(ev_exact[:2]), atol=1e-3)
    # eigenvectors match up to sign/rotation: compare projectors
    p_exact = np.asarray(y_exact) @ np.asarray(y_exact).T
    p_sub = np.asarray(y_sub) @ np.asarray(y_sub).T
    np.testing.assert_allclose(p_sub, p_exact, atol=1e-2)


def test_subspace_clustering_separates_blobs():
    x, labels = blobs()
    assign, _, _ = spectral_cluster(KEY, jnp.asarray(x), 2, solver="subspace")
    assert purity(np.asarray(assign), labels, 2) >= 0.95


def test_nystrom_pallas_path_agrees():
    x, _ = blobs(n=96)
    y_jnp, _ = nystrom_spectral_embedding(KEY, jnp.asarray(x), 2, 24,
                                          gamma=0.5, use_pallas=False)
    y_pal, _ = nystrom_spectral_embedding(KEY, jnp.asarray(x), 2, 24,
                                          gamma=0.5, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_pal),
                               atol=1e-3)


def test_nystrom_all_landmarks_degenerates_gracefully():
    """m = n (every point a landmark) must still cluster correctly."""
    x, labels = blobs(n=64)
    assign, _, _ = spectral_cluster(KEY, jnp.asarray(x), 2,
                                    method="nystrom", num_landmarks=64)
    assert purity(np.asarray(assign), labels, 2) >= 0.95


def test_incompatible_knob_combinations_rejected():
    """solver is a dense-path knob, num_landmarks a nystrom-path knob;
    silently ignoring either would let callers benchmark the wrong
    algorithm."""
    x = jnp.asarray(blobs(n=32)[0])
    with pytest.raises(ValueError, match="num_landmarks"):
        spectral_cluster(KEY, x, 2, method="dense", num_landmarks=8)
    with pytest.raises(ValueError, match="solver"):
        spectral_cluster(KEY, x, 2, method="nystrom", solver="subspace")


def test_dqre_sc_auto_k_nystrom_avoids_dense_path(monkeypatch):
    """auto_k with approx_method='nystrom' must estimate the eigengap
    from the landmark spectrum — building the dense affinity would
    reintroduce the O(N²) ceiling."""
    import repro.core.spectral as S
    from repro.core.selection import DQREScSelection, RoundState

    def boom(*a, **kw):
        raise AssertionError("dense affinity built on the nystrom path")

    monkeypatch.setattr(S, "affinity_matrix", boom)
    x, _ = blobs(n=64, k=2)
    pol = DQREScSelection(64, 8, 4, seed=0, num_clusters=4, auto_k=True,
                          approx_method="nystrom", num_landmarks=16)
    sel = pol.select(RoundState(0, x, np.zeros(4, np.float32), 0.1))
    assert len(set(sel.tolist())) == 8


@pytest.mark.slow
def test_dqre_sc_select_100k_clients():
    """Acceptance: a 100k-client cohort selection completes (in seconds on
    CPU) via the Nyström path, where the dense path would OOM on the
    10¹⁰-entry affinity matrix."""
    from repro.core.selection import DQREScSelection, RoundState
    n, d = 100_000, 8
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(8, d)) * 6
    embeds = (centers[rng.integers(0, 8, n)]
              + rng.normal(size=(n, d))).astype(np.float32)
    pol = DQREScSelection(n, 64, d, seed=0, num_clusters=8,
                          approx_method="nystrom", num_landmarks=512)
    sel = pol.select(RoundState(0, embeds, np.zeros(d, np.float32), 0.1))
    assert len(sel) == 64
    assert len(set(sel.tolist())) == 64
