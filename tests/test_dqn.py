"""DQN (current + target networks): learning on a 2-armed bandit MDP."""

import jax
import numpy as np
import pytest

from repro.core import DQNAgent, DQNConfig, qnet_apply, qnet_init

KEY = jax.random.PRNGKey(0)


def mk_agent(**kw):
    cfg = DQNConfig(state_dim=4, num_actions=2, hidden=(32,),
                    eps_decay_steps=50, target_sync_every=5, **kw)
    return DQNAgent(KEY, cfg)


def test_epsilon_decays():
    agent = mk_agent()
    e0 = agent.epsilon()
    agent.steps = 100
    assert agent.epsilon() < e0
    assert abs(agent.epsilon() - agent.cfg.eps_end) < 1e-6


def test_target_network_syncs_periodically():
    agent = mk_agent()
    rng = np.random.default_rng(0)
    s = np.ones(4, np.float32)   # nonzero so first-layer weights get grads
    for _ in range(20):
        agent.observe(s, 0, 1.0, s)
    before = np.asarray(agent.target_params[0]["w"]).copy()
    for _ in range(agent.cfg.target_sync_every):
        agent.train_step(rng)
    after = np.asarray(agent.target_params[0]["w"])
    assert not np.allclose(before, after)
    np.testing.assert_allclose(after, np.asarray(agent.params[0]["w"]))


@pytest.mark.slow
def test_learns_bandit_preference():
    """Action 1 always pays 1, action 0 pays 0 — Q(s,1) must end higher."""
    agent = mk_agent()
    rng = np.random.default_rng(0)
    s = np.ones(4, np.float32)
    for _ in range(200):
        agent.observe(s, 1, 1.0, s)
        agent.observe(s, 0, 0.0, s)
        agent.train_step(rng)
    q = agent.q_values(s)
    assert q[1] > q[0] + 0.2


def test_act_greedy_after_decay():
    agent = mk_agent()
    rng = np.random.default_rng(0)
    s = np.ones(4, np.float32)
    for _ in range(200):
        agent.observe(s, 1, 1.0, s)
        agent.observe(s, 0, 0.0, s)
        agent.train_step(rng)
    agent.steps = 10_000          # epsilon at floor
    acts = [agent.act(rng, s) for _ in range(20)]
    assert np.mean(acts) > 0.7


def test_train_step_defers_host_sync_last_loss_lazy():
    """Regression (repro-lint jax-blocking-sync): train_step must return
    the loss as a device scalar — the serving path calls it under the
    select lock — and materialize only via the last_loss property."""
    agent = mk_agent()
    rng = np.random.default_rng(0)
    for _ in range(16):
        s = rng.normal(size=4).astype(np.float32)
        agent.observe(s, int(rng.integers(2)), 1.0, s)
    out = agent.train_step(rng)
    assert not isinstance(out, float)      # stayed on device
    assert isinstance(agent.last_loss, float)
    assert np.isfinite(agent.last_loss)
