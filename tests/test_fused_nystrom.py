"""Streaming fused Nyström pipeline vs the composed jnp reference.

Three layers of guarantees:

* kernel-level — every fused pass (`nystrom_colsum/gram/extension`,
  `panel_matmul`, `quantized_cross_affinity`) matches its naive oracle
  in ``kernels/ref.py`` across shapes, row-panel sizes, and all three
  ``affinity_dtype`` tile precisions;
* pipeline-level — `nystrom_from_landmarks(fused=True)` agrees with the
  ``fused=False`` jnp composition on every ROTATION-INVARIANT quantity
  (spectrum, the y·yᵀ projector, cluster partitions).  Raw embeddings
  are deliberately not compared: well-separated clusters make the
  leading eigenspace degenerate, so the ~1e-7 tiled-accumulation
  differences rotate individual eigenvectors arbitrarily;
* system-level — quantized (bf16/int8) engine solves hold the purity
  floor on the skewed non-IID fixture, and the `use_pallas` toggle is
  thread-safe.

A hypothesis block (skipped without the 'dev' extra) fuzzes the
kernel-vs-oracle agreement over random shapes.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cohort.engine import CohortConfig, CohortEngine
from repro.cohort.eigensolver import _blocked_matmul, subspace_topk
from repro.cohort.nystrom import nystrom_from_landmarks
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)
DTYPES = ("f32", "bf16", "int8")


def blobs(n=509, k=4, sep=8.0, d=8, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * sep
    labels = rng.integers(0, k, n)
    x = (centers[labels] + rng.normal(size=(n, d))).astype(np.float32)
    return x, labels


def skewed_blobs(seed=0, d=8, sep=10.0):
    """Non-IID fixture: a head cluster with 75 % of the clients + 5 tails."""
    rng = np.random.default_rng(seed)
    sizes = [450, 30, 30, 30, 30, 30]
    centers = rng.normal(size=(len(sizes), d)) * sep
    labels = np.repeat(np.arange(len(sizes)), sizes)
    x = (centers[labels]
         + rng.normal(size=(len(labels), d))).astype(np.float32)
    return x, labels


def purity(assign, labels):
    assign = np.asarray(assign)
    return sum(np.bincount(labels[assign == c]).max()
               for c in np.unique(assign)) / len(labels)


def same_partition(a, b):
    a, b = np.asarray(a), np.asarray(b)
    pairs = {(int(x), int(y)) for x, y in zip(a, b)}
    return len(pairs) == len(set(a)) == len(set(b))


def _fixture(n=261, m=65, d=7, k=5, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    mask = jnp.asarray((rng.random(n) > 0.1).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(m,)) ** 2 + 0.1, jnp.float32)
    wis = jnp.asarray(rng.normal(size=(m, m)), jnp.float32)
    proj = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    return x, z, 0.37, mask, u, wis, proj


# -- kernel vs oracle -------------------------------------------------------

@pytest.mark.parametrize("affinity_dtype", DTYPES)
@pytest.mark.parametrize("block_m", [32, 128, 1024])
def test_fused_passes_match_oracles(affinity_dtype, block_m):
    x, z, gamma, mask, u, wis, proj = _fixture()
    kw = dict(affinity_dtype=affinity_dtype, block_m=block_m)
    np.testing.assert_allclose(
        ops.nystrom_colsum(x, z, gamma, mask, **kw),
        ref.nystrom_colsum_ref(x, z, gamma, mask,
                               affinity_dtype=affinity_dtype),
        rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(
        ops.nystrom_gram(x, z, gamma, u, wis, mask, **kw),
        ref.nystrom_gram_ref(x, z, gamma, u, wis, mask,
                             affinity_dtype=affinity_dtype),
        rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(
        ops.nystrom_extension(x, z, gamma, u, proj, mask, **kw),
        ref.nystrom_extension_ref(x, z, gamma, u, proj, mask,
                                  affinity_dtype=affinity_dtype),
        rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        ops.quantized_cross_affinity(x, z, gamma, **kw),
        ref.quantized_cross_affinity_ref(x, z, gamma,
                                         affinity_dtype=affinity_dtype),
        rtol=2e-5, atol=2e-4)


def test_unmasked_equals_ones_mask():
    x, z, gamma, _, u, wis, proj = _fixture()
    ones = jnp.ones((x.shape[0],), jnp.float32)
    np.testing.assert_array_equal(ops.nystrom_colsum(x, z, gamma),
                                  ops.nystrom_colsum(x, z, gamma, ones))


def test_f32_quantized_cross_is_bitwise_legacy_kernel():
    """affinity_dtype="f32" must reproduce the PR-1 cross kernel exactly
    — the fused path's W block stays backend-consistent with it."""
    x, z, gamma, *_ = _fixture()
    a = ops.quantized_cross_affinity(x, z, gamma, affinity_dtype="f32")
    b = ops.rbf_cross_affinity(x, z, gamma)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_extension_rows_unit_norm_masked_rows_zero():
    x, z, gamma, mask, u, _, proj = _fixture()
    v = np.asarray(ops.nystrom_extension(x, z, gamma, u, proj, mask))
    norms = np.linalg.norm(v, axis=1)
    live = np.asarray(mask) > 0
    np.testing.assert_allclose(norms[live], 1.0, atol=1e-5)
    assert np.abs(v[~live]).max() == 0.0


def test_masked_rows_equal_truncated_input():
    """Zero-masked trailing rows must reproduce the solve on the prefix —
    the invariant the shard_map global padding relies on."""
    x, z, gamma, _, u, wis, proj = _fixture(n=300)
    n_live = 211
    mask = (jnp.arange(300) < n_live).astype(jnp.float32)
    np.testing.assert_allclose(
        ops.nystrom_colsum(x, z, gamma, mask, block_m=64),
        ops.nystrom_colsum(x[:n_live], z, gamma, block_m=64),
        rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(
        ops.nystrom_gram(x, z, gamma, u, wis, mask, block_m=64),
        ops.nystrom_gram(x[:n_live], z, gamma, u, wis, block_m=64),
        rtol=1e-4, atol=1e-4)


# -- eigensolver panel matmul ----------------------------------------------

def test_panel_matmul_bitwise_blocked_matmul():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(size=(130, 130)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(130, 9)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ops.panel_matmul(w, q, block_rows=32)),
        np.asarray(_blocked_matmul(w, q, 32)))
    np.testing.assert_allclose(ops.panel_matmul(w, q, block_rows=32),
                               ref.panel_matmul_ref(w, q),
                               rtol=1e-5, atol=1e-5)


def test_subspace_topk_pallas_route_agrees():
    rng = np.random.default_rng(3)
    a = rng.normal(size=(96, 96)).astype(np.float32)
    w = jnp.asarray(a @ a.T)
    e0, v0 = subspace_topk(w, 6, iters=40, block_rows=32, use_pallas=False)
    e1, v1 = subspace_topk(w, 6, iters=40, block_rows=32, use_pallas=True)
    np.testing.assert_allclose(e0, e1, rtol=1e-4, atol=1e-4)
    # compare subspaces via projectors (eigenvector signs are arbitrary)
    np.testing.assert_allclose(v0 @ v0.T, v1 @ v1.T, atol=1e-3)


# -- fused pipeline vs composed jnp reference ------------------------------

@pytest.mark.parametrize("affinity_dtype", DTYPES)
def test_fused_pipeline_matches_composed_reference(affinity_dtype):
    """Rotation-invariant agreement: spectrum tight for f32, within the
    quantization budget for bf16/int8; projector + partition for all."""
    from repro.core.kmeans import kmeans

    x, labels = blobs(n=700)
    x = jnp.asarray(x)
    k = 4
    idx = jnp.asarray(
        np.random.default_rng(1).choice(700, 96, replace=False))
    gamma = 0.05
    y0, e0, _, _ = nystrom_from_landmarks(x, idx, k, gamma)
    y1, e1, _, _ = nystrom_from_landmarks(x, idx, k, gamma, fused=True,
                                          affinity_dtype=affinity_dtype)
    tol = 1e-3 if affinity_dtype == "f32" else 2e-2
    np.testing.assert_allclose(e0[:k + 1], e1[:k + 1], atol=tol)
    np.testing.assert_allclose(np.asarray(y0 @ y0.T),
                               np.asarray(y1 @ y1.T), atol=5e-2)
    a0, _ = kmeans(KEY, y0, k)
    a1, _ = kmeans(KEY, y1, k)
    assert same_partition(a0, a1)
    assert purity(a1, labels) >= purity(a0, labels) - 1e-3


def test_fused_subspace_solver_pipeline():
    """The fused path composes with the blocked subspace eigensolver
    (warm-startable route) — partition must match the composed path."""
    from repro.core.kmeans import kmeans

    x, labels = blobs(n=600)
    x = jnp.asarray(x)
    k = 4
    idx = jnp.asarray(
        np.random.default_rng(4).choice(600, 64, replace=False))
    gamma = 0.05
    kw = dict(w_solver="subspace", w_rank=32, mm_solver="subspace",
              iters=40, key=KEY, block_rows=32)
    y0, e0, _, _ = nystrom_from_landmarks(x, idx, k, gamma, **kw)
    y1, e1, _, _ = nystrom_from_landmarks(x, idx, k, gamma, fused=True,
                                          **kw)
    np.testing.assert_allclose(e0[:k], e1[:k], atol=1e-3)
    a0, _ = kmeans(KEY, y0, k)
    a1, _ = kmeans(KEY, y1, k)
    assert same_partition(a0, a1)
    assert purity(a1, labels) >= 0.95


# -- engine-level quantized purity floor (skewed non-IID fixture) ----------

@pytest.mark.parametrize("affinity_dtype", DTYPES)
@pytest.mark.parametrize("method", ["nystrom", "sharded"])
def test_quantized_engine_purity_floor_on_skewed_fixture(method,
                                                         affinity_dtype):
    """The acceptance gate: quantized tiles must not cost clustering
    quality on the non-IID population the paper targets."""
    x, labels = skewed_blobs()
    eng = CohortEngine(CohortConfig(num_clusters=6, method=method,
                                    num_landmarks=96, use_pallas=True,
                                    affinity_dtype=affinity_dtype),
                       seed=0)
    res = eng.select(x)
    assert purity(res.assign, labels) >= 0.95


def test_engine_affinity_dtype_validation():
    with pytest.raises(ValueError, match="affinity_dtype"):
        CohortConfig(affinity_dtype="fp8", use_pallas=True)
    with pytest.raises(ValueError, match="requires use_pallas"):
        CohortConfig(affinity_dtype="int8")


# -- thread-safe substrate toggle ------------------------------------------

def test_use_pallas_toggle_thread_safety_and_scoping():
    """Hammer the toggle from many threads; the flag must always be a
    bool (no torn state) and every scope must restore what it saw."""
    base = ops.use_pallas()
    errors = []

    def hammer(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(200):
                if rng.random() < 0.5:
                    with ops.use_pallas_scoped(bool(rng.random() < 0.5)):
                        assert ops.use_pallas() in (True, False)
                else:
                    ops.set_use_pallas(bool(rng.random() < 0.5))
        except Exception as exc:  # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    ops.set_use_pallas(base)
    with ops.use_pallas_scoped(not base):
        assert ops.use_pallas() is (not base)
    assert ops.use_pallas() is base


# -- hypothesis fuzzing (needs the 'dev' extra) ----------------------------
# Conditionally defined (not importorskip): this module's deterministic
# coverage must still run where hypothesis is absent.

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised in the dev env
    @pytest.mark.skip(
        reason="property tests need the 'dev' extra (pip install -e .[dev])")
    def test_fuzz_fused_passes_match_oracles():
        pass
else:
    _settings = settings(max_examples=15, deadline=None)

    @_settings
    @given(st.integers(3, 80), st.integers(2, 24), st.integers(1, 6),
           st.sampled_from([8, 32, 128]), st.sampled_from(DTYPES),
           st.integers(0, 2 ** 31 - 1))
    def test_fuzz_fused_passes_match_oracles(n, m, d, block_m,
                                             affinity_dtype, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(m,)) ** 2 + 0.1, jnp.float32)
        wis = jnp.asarray(rng.normal(size=(m, m)), jnp.float32)
        proj = jnp.asarray(rng.normal(size=(m, 3)), jnp.float32)
        gamma = float(rng.uniform(0.01, 1.0))
        kw = dict(affinity_dtype=affinity_dtype, block_m=block_m)
        np.testing.assert_allclose(
            ops.nystrom_colsum(x, z, gamma, **kw),
            ref.nystrom_colsum_ref(x, z, gamma,
                                   affinity_dtype=affinity_dtype),
            rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(
            ops.nystrom_gram(x, z, gamma, u, wis, **kw),
            ref.nystrom_gram_ref(x, z, gamma, u, wis,
                                 affinity_dtype=affinity_dtype),
            rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(
            ops.nystrom_extension(x, z, gamma, u, proj, **kw),
            ref.nystrom_extension_ref(x, z, gamma, u, proj,
                                      affinity_dtype=affinity_dtype),
            rtol=5e-3, atol=5e-3)
