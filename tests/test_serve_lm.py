"""Continuous-batching LM decode: oracle exactness, scheduler behavior.

The load-bearing guarantee is the batch-1 oracle: every request served
from a heterogeneous batch must generate the SAME tokens, bit-exact, as
serving that request alone.  That only holds if per-request cache
positions confine each row's KV reads to its own prefix and slot reuse
never leaks a prior occupant's state.
"""

import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import DecodeScheduler, Request, Server


def _make_server(arch="qwen2-7b", batch=2, max_seq=48, **kw):
    return Server(get_config(arch).reduced(), batch, max_seq, **kw)


def _reqs(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size, p).astype(np.int32),
                    g)
            for i, (p, g) in enumerate(lens)]


def _oracle(cfg, req, max_seq, seed=0):
    solo = Server(cfg, 1, max_seq, seed=seed)
    r = Request(req.uid, req.prompt, req.max_new_tokens)
    solo.serve_batch([r])
    return r.generated


# ---------------------------------------------------------------------------
# mixed-length golden: batch continuations == batch-1 oracle
# ---------------------------------------------------------------------------

LENGTH_PATTERNS = [
    # (prompt_len, max_new_tokens) per request; each exercises a
    # distinct mixed-length shape (more requests than slots, a
    # same-length pair, and extreme skew)
    [(5, 6), (11, 4), (2, 8), (7, 3), (16, 5)],
    [(8, 4), (8, 4), (3, 7)],
    [(1, 9), (20, 2), (13, 6)],
]


@pytest.mark.parametrize("lens", LENGTH_PATTERNS)
def test_mixed_length_greedy_matches_batch1_oracle(lens):
    """Every continuation from a heterogeneous greedy batch is
    bit-identical to decoding that request alone — pad and stale-slot
    KV can never leak into another row's attention."""
    cfg = get_config("qwen2-7b").reduced()
    srv = Server(cfg, batch=2, max_seq=48, seed=0)
    done = srv.serve_batch(_reqs(cfg, lens))
    assert len(done) == len(lens)
    for r in done:
        assert len(r.generated) == r.max_new_tokens
        assert r.generated == _oracle(cfg, r, 48)


@pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "deepseek-v3-671b"])
def test_mixed_length_oracle_hybrid_and_mla(arch):
    """Slot-targeted prefill must also be exact for SSM/conv state
    (mamba hybrid) and the compressed-latent cache (MLA)."""
    cfg = get_config(arch).reduced()
    srv = Server(cfg, batch=2, max_seq=32, seed=0)
    done = srv.serve_batch(_reqs(cfg, [(4, 4), (9, 3), (3, 5)], seed=1))
    for r in done:
        assert r.generated == _oracle(cfg, r, 32)


# ---------------------------------------------------------------------------
# scheduler unit tests
# ---------------------------------------------------------------------------

def test_admit_retire_ordering_more_requests_than_slots():
    """With R > slots, every request is eventually admitted exactly
    once and retired exactly once; queue drains to empty."""
    srv = _make_server(batch=2, max_seq=32, seed=0)
    cfg = srv.cfg
    done = srv.serve_batch(_reqs(cfg, [(4, 3)] * 7))
    assert sorted(r.uid for r in done) == list(range(7))
    s = srv.stats()
    assert s["admitted"] == 7
    assert s["retired"] == 7
    assert s["occupied"] == 0
    assert s["queue_depth"] == 0


def test_slot_reuse_never_leaks_prior_kv():
    """A request admitted into a freed slot generates the same tokens
    as when the slot was never previously occupied."""
    cfg = get_config("qwen2-7b").reduced()
    rng = np.random.default_rng(3)
    probe = Request(99, rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    5)
    # fresh engine: probe runs in a never-used slot
    fresh = _oracle(cfg, probe, 32)
    # dirty engine, batch=1: a long noisy request occupies slot 0 first,
    # then the probe is admitted into the SAME slot after it retires
    srv = Server(cfg, 1, 32, seed=0)
    noise = Request(0, rng.integers(0, cfg.vocab_size, 20).astype(np.int32),
                    8)
    reused = Request(99, probe.prompt, 5)
    srv.serve_batch([noise, reused])
    assert reused.generated == fresh


def test_deterministic_under_fixed_seed_with_temperature():
    """Gumbel-max sampling replays identically for identical seeds and
    diverges across seeds (i.e. it is actually sampling)."""
    cfg = get_config("qwen2-7b").reduced()
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 9, 6)]

    def run(seed):
        srv = Server(cfg, 2, 32, seed=seed, temperature=0.9)
        out = srv.serve_batch([Request(i, p, 6)
                               for i, p in enumerate(prompts)])
        return [r.generated for r in out]

    assert run(11) == run(11)
    assert run(11) != run(12)


def test_partial_batch_runs_no_filler_steps():
    """One request on a 4-slot server: decode_tokens counts exactly the
    real tokens (max_new_tokens - 1 post-prefill) — empty slots are
    masked inactive, not padded with filler requests."""
    srv = _make_server(batch=4, max_seq=24, seed=0)
    cfg = srv.cfg
    rng = np.random.default_rng(0)
    srv.serve_batch(
        [Request(7, rng.integers(0, cfg.vocab_size, 6).astype(np.int32), 5)])
    s = srv.stats()
    assert s["decode_tokens"] == 4        # 5 tokens: 1 prefill + 4 decode
    assert s["decode_steps"] == 4
    assert s["tokens_generated"] == 5


def test_tok_s_counts_only_real_tokens():
    """last_decode_tok_s == real decode tokens / decode seconds — the
    old lockstep loop divided batch*steps by wall time even when most
    slots were filler."""
    srv = _make_server(batch=4, max_seq=24, seed=0)
    cfg = srv.cfg
    rng = np.random.default_rng(0)
    srv.serve_batch(
        [Request(0, rng.integers(0, cfg.vocab_size, 4).astype(np.int32), 6)])
    s = srv.stats()
    expect = s["decode_tokens"] / max(s["decode_seconds"], 1e-9)
    assert srv.last_decode_tok_s == pytest.approx(expect)
    # the old bug would have reported batch * steps / dt = 4x this
    assert srv.last_decode_tok_s < 2 * expect


def test_zero_token_requests_complete_without_slots():
    """max_new_tokens=0 completes immediately: no prefill, no decode."""
    srv = _make_server(batch=2, max_seq=16, seed=0)
    cfg = srv.cfg
    rng = np.random.default_rng(0)
    reqs = [Request(0, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    0),
            Request(1, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    3)]
    done = {r.uid: r for r in srv.serve_batch(reqs)}
    assert done[0].generated == []
    assert len(done[1].generated) == 3
    s = srv.stats()
    assert s["prefills"] == 1             # only the real request


def test_truncation_at_cache_capacity():
    """A request whose generation would overflow max_seq retires early
    with what it produced and is counted as truncated."""
    srv = _make_server(batch=1, max_seq=10, seed=0)
    cfg = srv.cfg
    rng = np.random.default_rng(0)
    r = Request(0, rng.integers(0, cfg.vocab_size, 8).astype(np.int32), 50)
    srv.serve_batch([r])
    # pos runs 8..9 -> 2 decode writes + the prefill token = 3 tokens
    assert 1 <= len(r.generated) < 50
    assert srv.stats()["truncated"] == 1


def test_submit_validates_prompt_length():
    srv = _make_server(batch=1, max_seq=8, seed=0)
    sched = srv.scheduler
    with pytest.raises(ValueError):
        sched.submit(Request(0, np.zeros(0, np.int32), 3))
    with pytest.raises(ValueError):
        sched.submit(Request(1, np.zeros(9, np.int32), 3))


def test_stats_shape_mirrors_cohort_server():
    """stats() exposes the serving dashboard keys the docs promise."""
    srv = _make_server(batch=2, max_seq=16, seed=0)
    s = srv.stats()
    for key in ("slots", "occupied", "queue_depth", "admitted", "retired",
                "truncated", "prefills", "decode_steps", "decode_tokens",
                "tokens_generated", "decode_seconds", "tok_s_ema",
                "last_decode_tok_s"):
        assert key in s, key
    assert s["slots"] == 2 and s["occupied"] == 0


def test_prefill_bucketing_is_result_invariant():
    """Bucketed prompt padding bounds jit retraces without changing a
    single generated token (write-before-read makes pad KV unreachable)."""
    cfg = get_config("qwen2-7b").reduced()
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    outs = []
    for bucket in (1, 4, 16):
        srv = Server(cfg, 1, 32, seed=0, prefill_bucket=bucket)
        outs.append(srv.serve_batch([Request(0, prompt, 6)])[0].generated)
    assert outs[0] == outs[1] == outs[2]


def test_scheduler_lock_order_registered():
    """The scheduler's locks participate in the serving lock order."""
    from repro.analysis import SERVING_LOCK_ORDER
    assert SERVING_LOCK_ORDER["_sched_lock"] < \
        SERVING_LOCK_ORDER["_stats_lock"]
