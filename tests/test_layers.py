import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


KEY = jax.random.PRNGKey(0)


def test_dense_shapes_and_bias():
    p = L.dense_init(KEY, 8, 16, bias=True, dtype="float32")
    x = jax.random.normal(KEY, (3, 8))
    y = L.dense(p, x)
    assert y.shape == (3, 16)
    assert np.allclose(y, x @ p["w"] + p["b"], atol=1e-6)


def test_rmsnorm_unit_scale_gives_unit_rms():
    p = L.rmsnorm_init(32, dtype="float32")
    x = jax.random.normal(KEY, (4, 32)) * 7.0
    y = L.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    assert np.allclose(rms, 1.0, atol=1e-3)


def test_layernorm_zero_mean_unit_var():
    p = L.layernorm_init(64, dtype="float32")
    x = jax.random.normal(KEY, (4, 64)) * 3 + 5
    y = L.layernorm(p, x)
    assert np.allclose(jnp.mean(y, -1), 0.0, atol=1e-4)
    assert np.allclose(jnp.var(y, -1), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_is_relative():
    x = jax.random.normal(KEY, (1, 6, 2, 16))
    pos = jnp.arange(6)
    y = L.apply_rope(x, pos)
    assert np.allclose(jnp.linalg.norm(y, axis=-1),
                       jnp.linalg.norm(x, axis=-1), atol=1e-4)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(KEY, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 16))
    def dot(i, j):
        qi = L.apply_rope(q, jnp.array([i]))
        kj = L.apply_rope(k, jnp.array([j]))
        return float(jnp.sum(qi * kj))
    assert abs(dot(3, 5) - dot(10, 12)) < 1e-4


@pytest.mark.parametrize("act", ["swiglu", "geglu", "gelu", "relu"])
def test_mlp_acts(act):
    p = L.mlp_init(KEY, 16, 32, act=act, dtype="float32")
    y = L.mlp(p, jax.random.normal(KEY, (2, 16)), act=act)
    assert y.shape == (2, 16)
    assert not np.isnan(np.asarray(y)).any()


def test_embed_unembed_tied():
    p = L.embed_init(KEY, 100, 16, dtype="float32")
    toks = jnp.array([[1, 5, 99]])
    e = L.embed(p, toks)
    assert e.shape == (1, 3, 16)
    logits = L.unembed(p, e)
    assert logits.shape == (1, 3, 100)
