"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single CPU device; only launch/dryrun.py forces 512 placeholder devices
(in a subprocess for the dry-run tests)."""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
