"""Paper Table 2: communication rounds to target accuracy, per policy.

Validated claim (qualitative — DESIGN.md §1): DQRE-SCnet reaches the
accuracy target in no more rounds than random FedAvg under non-IID skew,
with FAVOR in between.  Absolute round counts differ from the paper
(synthetic datasets; see EXPERIMENTS.md §Repro).
"""

from __future__ import annotations

import time

from benchmarks.fl_common import MAX_ROUNDS, TARGETS, run_policy

POLICIES = ["fedavg", "kcenter", "favor", "dqre_sc"]
DATASETS = ["mnist", "fashion_mnist", "cifar10"]
SIGMA = 0.8


def run(csv_rows: list) -> None:
    for dataset in DATASETS:
        for policy in POLICIES:
            t0 = time.time()
            runner = run_policy(dataset, policy, SIGMA)
            rounds = runner.rounds_to_accuracy()
            final = runner.history[-1].accuracy
            us = (time.time() - t0) * 1e6
            csv_rows.append((
                f"table2/{dataset}/{policy}", us,
                f"rounds_to_{TARGETS[dataset]:.2f}="
                f"{rounds if rounds else f'>{MAX_ROUNDS}'};"
                f"final_acc={final:.4f}"))
