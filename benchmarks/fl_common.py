"""Shared FL-benchmark harness (paper Tables 2/3, Fig. 6).

CPU-budgeted defaults: the paper ran hundreds of rounds on 100 clients;
the bench defaults scale that down (REPRO_BENCH_SCALE=full restores
paper-scale settings).  All comparisons are *relative* across policies on
identical seeds/partitions, which is the claim being validated.
"""

from __future__ import annotations

import os

from repro.fed import FederatedRunner, RunnerConfig

FULL = os.environ.get("REPRO_BENCH_SCALE", "quick") == "full"

DEFAULTS = dict(
    num_clients=100 if FULL else 20,
    clients_per_round=10 if FULL else 5,
    local_steps=20 if FULL else 8,
    batch_size=32 if FULL else 16,
    train_size=None if FULL else 2500,
    eval_size=2048 if FULL else 384,
    embed_dim=8,
    num_clusters=8 if FULL else 4,
)

MAX_ROUNDS = 300 if FULL else 15

# per-dataset target accuracies (synthetic stand-ins are easier than the
# real datasets; targets chosen so policies differentiate mid-training)
TARGETS = {"mnist": 0.90, "fashion_mnist": 0.80, "cifar10": 0.60}


def run_policy(dataset: str, policy: str, sigma: float, seed: int = 0,
               max_rounds: int = None, **overrides):
    cfg = RunnerConfig(dataset=dataset, policy=policy, sigma=sigma,
                       target_accuracy=TARGETS[dataset], seed=seed,
                       **{**DEFAULTS, **overrides})
    runner = FederatedRunner(cfg)
    runner.run(max_rounds or MAX_ROUNDS, stop_at_target=True)
    return runner
