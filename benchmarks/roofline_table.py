"""§Roofline: aggregate the dry-run JSON records into the baseline table
(one row per arch × shape; single-pod mesh)."""

from __future__ import annotations

import glob
import json
import os


def load_records(pattern="experiments/dryrun/*__16x16.json"):
    recs = []
    for path in sorted(glob.glob(pattern)):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def format_table(recs) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_ms':>10s} {'memory_ms':>10s}"
           f" {'coll_ms':>9s} {'bound':>10s} {'useful':>7s} {'GiB/dev':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:24s} {r['shape']:12s} FAILED: "
                         f"{r.get('error', '?')[:60]}")
            continue
        rf = r["roofline"]
        mem = (r["memory"].get("peak_bytes") or 0) / 2 ** 30
        ratio = rf.get("useful_flop_ratio") or 0.0
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {rf['compute_s']*1e3:10.2f} "
            f"{rf['memory_s']*1e3:10.2f} {rf['collective_s']*1e3:9.2f} "
            f"{rf['bottleneck']:>10s} {ratio:7.3f} {mem:8.2f}")
    return "\n".join(lines)


def run(csv_rows: list) -> None:
    recs = load_records()
    for r in recs:
        if r.get("status") != "ok":
            csv_rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                             f"FAILED:{r.get('error','')[:80]}"))
            continue
        rf = r["roofline"]
        csv_rows.append((
            f"roofline/{r['arch']}/{r['shape']}",
            rf["step_s_bound"] * 1e6,
            f"bottleneck={rf['bottleneck']};"
            f"compute_ms={rf['compute_s']*1e3:.2f};"
            f"memory_ms={rf['memory_s']*1e3:.2f};"
            f"collective_ms={rf['collective_s']*1e3:.2f};"
            f"useful_ratio={rf.get('useful_flop_ratio') or 0:.3f}"))
    if recs:
        os.makedirs("experiments", exist_ok=True)
        with open("experiments/roofline_table.txt", "w") as f:
            f.write(format_table(recs) + "\n")
