"""Kernel micro-benchmarks: jnp reference path timings (the production
CPU path) + interpret-mode Pallas validation cost.  On TPU the same
harness times the compiled kernels."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)                      # compile / warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def _bench_spectral_selection(csv_rows, key):
    """Dense Algorithm I vs the Nyström landmark path.

    At n = 4096 the dense path pays O(n²d) affinity + O(n³) eigh; the
    Nyström path with m = n/8 landmarks is O(n·m·d + m³) and should be
    >= 10x faster wall-clock.  The 100k row demonstrates the cohort scale
    the dense path cannot reach at all (10¹⁰-entry affinity matrix).
    """
    from repro.core.spectral import spectral_cluster

    n, d, k, m = 4096, 8, 8, 512
    x = jax.random.normal(key, (n, d), jnp.float32) * 4.0

    us_dense = _time(
        lambda a: spectral_cluster(key, a, k, method="dense"), x, iters=1)
    us_nys = _time(
        lambda a: spectral_cluster(key, a, k, method="nystrom",
                                   num_landmarks=m), x, iters=1)
    csv_rows.append((f"spectral/dense/n{n}", us_dense, ""))
    csv_rows.append((f"spectral/nystrom_m{m}/n{n}", us_nys,
                     f"speedup={us_dense / us_nys:.1f}x"))

    n_big = 100_000
    xb = jax.random.normal(jax.random.fold_in(key, 7), (n_big, d)) * 4.0
    us_big = _time(
        lambda a: spectral_cluster(key, a, k, method="nystrom",
                                   num_landmarks=m), xb, iters=1)
    csv_rows.append((f"spectral/nystrom_m{m}/n{n_big}", us_big,
                     f"clients_per_sec={n_big / (us_big / 1e6):.0f}"))


def _bench_cohort(csv_rows, key):
    """Dense vs Nyström vs sharded-Nyström cohort selection wall time.

    Engine-level end-to-end timings (landmarks + eigensolve + k-means)
    at the three cohort scales; dense is only feasible at n = 4096 (the
    32k/100k affinity matrices are 4/40 GB).  Emits ``BENCH_cohort.json``
    alongside the CSV rows so the sweep is machine-readable.
    """
    import json

    from repro.cohort import CohortConfig, CohortEngine

    k, d, m = 8, 8, 512
    devices = len(jax.devices())
    records = []
    for n in (4096, 32768, 100_000):
        x = jax.random.normal(jax.random.fold_in(key, n), (n, d),
                              jnp.float32) * 4.0
        x = jax.device_get(x)
        row = {"n": n, "devices": devices, "num_landmarks": m,
               "dense_us": None, "nystrom_us": None, "sharded_us": None}
        methods = (["dense"] if n <= 4096 else []) + ["nystrom", "sharded"]
        for method in methods:
            cfg = CohortConfig(
                num_clusters=k, method=method,
                num_landmarks=None if method == "dense" else m)

            def run_once(a, cfg=cfg):
                # fresh engine per call: the fingerprint cache would
                # otherwise turn the timed call into a no-op
                return CohortEngine(cfg, seed=0).select(a).assign

            us = _time(run_once, x, iters=1)
            row[f"{method}_us"] = us
            csv_rows.append((f"cohort/{method}/n{n}", us,
                             f"clients_per_sec={n / (us / 1e6):.0f}"))
        records.append(row)
    with open("BENCH_cohort.json", "w") as fh:
        json.dump({"unit": "us_per_select", "records": records}, fh,
                  indent=2)


def run(csv_rows: list) -> None:
    key = jax.random.PRNGKey(0)
    on_tpu = jax.default_backend() == "tpu"

    # pairwise distances (spectral clustering hotspot): n clients
    for n in (128, 512):
        x = jax.random.normal(key, (n, 16))
        us_ref = _time(jax.jit(ref.pairwise_sq_dists_ref), x, x)
        csv_rows.append((f"kernel/pairwise_ref/n{n}", us_ref,
                         f"bytes={n*n*4}"))
        if on_tpu:
            us_k = _time(lambda a, b: ops.pairwise_sq_dists(a, b), x, x)
            csv_rows.append((f"kernel/pairwise_pallas/n{n}", us_k, ""))
            z = x[:n // 8]
            us_c = _time(lambda a, b: ops.rbf_cross_affinity(a, b, 0.5),
                         x, z)
            csv_rows.append((f"kernel/cross_rbf_pallas/n{n}", us_c, ""))

    _bench_spectral_selection(csv_rows, key)
    _bench_cohort(csv_rows, key)

    # flash attention jnp-blocked vs naive at growing S
    from repro.models.attention import blocked_attention
    for S in (256, 1024):
        q = jax.random.normal(key, (1, S, 4, 64), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 2, 64))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, 2, 64))
        us_naive = _time(jax.jit(lambda a, b, c: ref.attention_ref(
            a, b, c, causal=True)), q, k, v)
        us_block = _time(jax.jit(lambda a, b, c: blocked_attention(
            a, b, c, causal=True)), q, k, v)
        csv_rows.append((f"kernel/attn_naive/S{S}", us_naive, ""))
        csv_rows.append((f"kernel/attn_blocked/S{S}", us_block,
                         f"vs_naive={us_block/us_naive:.2f}x"))

    # SSD chunked vs per-token scan cost proxy
    from repro.models import mamba as M
    from repro.configs import get_config
    cfg = get_config("mamba2-2.7b").reduced()
    p = M.mamba_init(key, cfg)
    x = jax.random.normal(key, (2, 128, cfg.d_model))
    us_ssd = _time(jax.jit(lambda a: M.mamba_apply(p, a, cfg)[0]), x)
    csv_rows.append(("kernel/ssd_chunked/S128", us_ssd, ""))
