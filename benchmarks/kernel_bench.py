"""Kernel micro-benchmarks: jnp reference path timings (the production
CPU path) + interpret-mode Pallas validation cost.  On TPU the same
harness times the compiled kernels."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)                      # compile / warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run(csv_rows: list) -> None:
    key = jax.random.PRNGKey(0)
    on_tpu = jax.default_backend() == "tpu"

    # pairwise distances (spectral clustering hotspot): n clients
    for n in (128, 512):
        x = jax.random.normal(key, (n, 16))
        us_ref = _time(jax.jit(ref.pairwise_sq_dists_ref), x, x)
        csv_rows.append((f"kernel/pairwise_ref/n{n}", us_ref,
                         f"bytes={n*n*4}"))
        if on_tpu:
            us_k = _time(lambda a, b: ops.pairwise_sq_dists(a, b), x, x)
            csv_rows.append((f"kernel/pairwise_pallas/n{n}", us_k, ""))

    # flash attention jnp-blocked vs naive at growing S
    from repro.models.attention import blocked_attention
    for S in (256, 1024):
        q = jax.random.normal(key, (1, S, 4, 64), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 2, 64))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, 2, 64))
        us_naive = _time(jax.jit(lambda a, b, c: ref.attention_ref(
            a, b, c, causal=True)), q, k, v)
        us_block = _time(jax.jit(lambda a, b, c: blocked_attention(
            a, b, c, causal=True)), q, k, v)
        csv_rows.append((f"kernel/attn_naive/S{S}", us_naive, ""))
        csv_rows.append((f"kernel/attn_blocked/S{S}", us_block,
                         f"vs_naive={us_block/us_naive:.2f}x"))

    # SSD chunked vs per-token scan cost proxy
    from repro.models import mamba as M
    from repro.configs import get_config
    cfg = get_config("mamba2-2.7b").reduced()
    p = M.mamba_init(key, cfg)
    x = jax.random.normal(key, (2, 128, cfg.d_model))
    us_ssd = _time(jax.jit(lambda a: M.mamba_apply(p, a, cfg)[0]), x)
    csv_rows.append(("kernel/ssd_chunked/S128", us_ssd, ""))
