"""Kernel micro-benchmarks: jnp reference path timings (the production
CPU path) + interpret-mode Pallas validation cost.  On TPU the same
harness times the compiled kernels.

Standalone entry for the CI gate on the fused Nyström pipeline:

  PYTHONPATH=src python -m benchmarks.kernel_bench --small --check

--small shrinks the fused sweep to CI size (and skips rewriting
``BENCH_cohort.json``); --check fails the process unless the fused
pipeline matches the unfused oracle (partition + leading evals) and the
quantized tile precisions hold the purity floor."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)                      # compile / warm
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def _bench_spectral_selection(csv_rows, key):
    """Dense Algorithm I vs the Nyström landmark path.

    At n = 4096 the dense path pays O(n²d) affinity + O(n³) eigh; the
    Nyström path with m = n/8 landmarks is O(n·m·d + m³) and should be
    >= 10x faster wall-clock.  The 100k row demonstrates the cohort scale
    the dense path cannot reach at all (10¹⁰-entry affinity matrix).
    """
    from repro.core.spectral import spectral_cluster

    n, d, k, m = 4096, 8, 8, 512
    x = jax.random.normal(key, (n, d), jnp.float32) * 4.0

    us_dense = _time(
        lambda a: spectral_cluster(key, a, k, method="dense"), x, iters=1)
    us_nys = _time(
        lambda a: spectral_cluster(key, a, k, method="nystrom",
                                   num_landmarks=m), x, iters=1)
    csv_rows.append((f"spectral/dense/n{n}", us_dense, ""))
    csv_rows.append((f"spectral/nystrom_m{m}/n{n}", us_nys,
                     f"speedup={us_dense / us_nys:.1f}x"))

    n_big = 100_000
    xb = jax.random.normal(jax.random.fold_in(key, 7), (n_big, d)) * 4.0
    us_big = _time(
        lambda a: spectral_cluster(key, a, k, method="nystrom",
                                   num_landmarks=m), xb, iters=1)
    csv_rows.append((f"spectral/nystrom_m{m}/n{n_big}", us_big,
                     f"clients_per_sec={n_big / (us_big / 1e6):.0f}"))


def _bench_cohort(csv_rows, key):
    """Dense vs Nyström vs sharded-Nyström cohort selection wall time.

    Engine-level end-to-end timings (landmarks + eigensolve + k-means)
    at the three cohort scales; dense is only feasible at n = 4096 (the
    32k/100k affinity matrices are 4/40 GB).  Emits ``BENCH_cohort.json``
    alongside the CSV rows so the sweep is machine-readable.
    """
    import json

    from repro.cohort import CohortConfig, CohortEngine

    k, d, m = 8, 8, 512
    devices = len(jax.devices())
    records = []
    for n in (4096, 32768, 100_000):
        x = jax.random.normal(jax.random.fold_in(key, n), (n, d),
                              jnp.float32) * 4.0
        x = jax.device_get(x)
        row = {"n": n, "devices": devices, "num_landmarks": m,
               "dense_us": None, "nystrom_us": None, "sharded_us": None}
        methods = (["dense"] if n <= 4096 else []) + ["nystrom", "sharded"]
        for method in methods:
            cfg = CohortConfig(
                num_clusters=k, method=method,
                num_landmarks=None if method == "dense" else m)

            def run_once(a, cfg=cfg):
                # fresh engine per call: the fingerprint cache would
                # otherwise turn the timed call into a no-op
                return CohortEngine(cfg, seed=0).select(a).assign

            us = _time(run_once, x, iters=1)
            row[f"{method}_us"] = us
            csv_rows.append((f"cohort/{method}/n{n}", us,
                             f"clients_per_sec={n / (us / 1e6):.0f}"))
        records.append(row)
    with open("BENCH_cohort.json", "w") as fh:
        json.dump({"unit": "us_per_select", "records": records}, fh,
                  indent=2)


def _peak_hbm_mb(n: int, m: int, d: int, k: int, variant: str) -> float:
    """Analytic peak-HBM estimate (f32 bytes) of each select variant.

    Counts the arrays that must coexist in device memory during the
    landmark solve: the dense path holds the n×n affinity; the unfused
    Nyström path holds C and its degree-scaled copy S (both (n, m))
    side by side; the fused streaming path holds NO (n, m) array — just
    the (n, d) input, the (n, k) output, and the m-sized replicated
    blocks, with each (block_m, m) affinity tile living only in VMEM.
    """
    f32 = 4
    if variant == "dense":
        total = n * n + n * d
    elif variant == "unfused":
        total = 2 * n * m + n * d + n * k + 3 * m * m
    else:  # fused (any affinity_dtype: tiles are quantized in-register)
        total = n * d + n * k + 3 * m * m + m * k
    return total * f32 / 1e6


def _bench_fused(csv_rows, key, *, small: bool = False,
                 check: bool = False):
    """Fused streaming pipeline vs the materialized paths + CI gate.

    Timings follow the ``_bench_cohort`` convention (fresh engine per
    timed call, jit caches warm from the untimed first call).  On this
    CPU container the kernels run in interpret mode, so the fused path
    trades the eliminated (n, m) HBM traffic for a 3× recompute of the
    affinity tile — the peak-memory column is the durable signal here;
    the wall-clock win belongs to memory-bound accelerators (see
    docs/BENCHMARKS.md caveats).  ``check=True`` enforces the
    correctness gates: fused-f32 must reproduce the unfused partition
    and leading spectrum, and bf16/int8 must hold the purity floor on
    a non-IID fixture.
    """
    import json
    import os

    import numpy as np

    from repro.cohort import CohortConfig, CohortEngine

    k, d = 8, 8
    m = 256 if small else 512
    sizes = (4096,) if small else (4096, 100_000)
    variants = [
        ("unfused", dict()),
        ("fused_f32", dict(use_pallas=True)),
        ("fused_bf16", dict(use_pallas=True, affinity_dtype="bf16")),
        ("fused_int8", dict(use_pallas=True, affinity_dtype="int8")),
    ]
    records = []
    for n in sizes:
        x = jax.device_get(jax.random.normal(
            jax.random.fold_in(key, 31 * n), (n, d), jnp.float32) * 4.0)
        row = {"n": n, "num_landmarks": m, "dense_us": None,
               "peak_hbm_mb": {
                   "dense": round(_peak_hbm_mb(n, m, d, k, "dense"), 2),
                   "unfused": round(_peak_hbm_mb(n, m, d, k, "unfused"), 2),
                   "fused": round(_peak_hbm_mb(n, m, d, k, "fused"), 2)}}
        if n <= 4096:
            cfg = CohortConfig(num_clusters=k, method="dense")
            row["dense_us"] = _time(
                lambda a, cfg=cfg: CohortEngine(cfg, seed=0).select(a).assign,
                x, iters=1)
            csv_rows.append((f"fused/dense/n{n}", row["dense_us"], ""))
        for name, overrides in variants:
            cfg = CohortConfig(num_clusters=k, method="sharded",
                               num_landmarks=m, **overrides)
            us = _time(
                lambda a, cfg=cfg: CohortEngine(cfg, seed=0).select(a).assign,
                x, iters=1)
            row[f"{name}_us"] = us
            note = (f"peak_hbm_mb="
                    f"{row['peak_hbm_mb']['fused' if 'fused' in name else 'unfused']}")
            csv_rows.append((f"fused/{name}/n{n}", us, note))
        records.append(row)

    if not small:
        # fold the sweep into BENCH_cohort.json as the "fused" section
        # (additive: _bench_cohort owns "records")
        payload = {}
        if os.path.exists("BENCH_cohort.json"):
            with open("BENCH_cohort.json") as fh:
                payload = json.load(fh)
        payload["fused"] = {"unit": "us_per_select", "records": records}
        with open("BENCH_cohort.json", "w") as fh:
            json.dump(payload, fh, indent=2)

    if not check:
        return

    # -- correctness gates (the CI contract) ----------------------------
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(4, d)) * 8.0
    sizes_sk = [1500, 180, 180, 140]          # skewed non-IID population
    labels = np.repeat(np.arange(4), sizes_sk)
    xg = (centers[labels]
          + rng.normal(size=(len(labels), d))).astype(np.float32)

    def solve(**overrides):
        cfg = CohortConfig(num_clusters=4, method="sharded",
                           num_landmarks=128, **overrides)
        return CohortEngine(cfg, seed=0).select(xg)

    def purity(assign):
        assign = np.asarray(assign)
        return sum(np.bincount(labels[assign == c]).max()
                   for c in np.unique(assign)) / len(labels)

    r_jnp = solve()
    r_f32 = solve(use_pallas=True)
    same = bool(np.all(
        (np.asarray(r_jnp.assign)[:, None] == np.asarray(r_jnp.assign)[None])
        == (np.asarray(r_f32.assign)[:, None]
            == np.asarray(r_f32.assign)[None])))
    ev_gap = float(np.max(np.abs(np.asarray(r_jnp.evals)[:4]
                                 - np.asarray(r_f32.evals)[:4])))
    p_f32 = purity(r_f32.assign)
    failures = []
    if not same:
        failures.append("fused f32 partition != unfused partition")
    if ev_gap > 1e-3:
        failures.append(f"fused f32 leading evals off by {ev_gap:.2e} "
                        f"(tolerance 1e-3)")
    for dtype in ("bf16", "int8"):
        p_q = purity(solve(use_pallas=True, affinity_dtype=dtype).assign)
        csv_rows.append((f"fused/purity_{dtype}", 0.0, f"purity={p_q:.4f}"))
        if p_q < 0.95 or p_q < p_f32 - 1e-3:
            failures.append(
                f"{dtype} purity {p_q:.4f} under the floor "
                f"(0.95 and f32 {p_f32:.4f} - 1e-3)")
    if failures:
        raise SystemExit("fused gate FAILED: " + "; ".join(failures))
    print(f"fused gate OK: partition match, leading-evals gap "
          f"{ev_gap:.2e}, f32 purity {p_f32:.4f}")


def run(csv_rows: list) -> None:
    key = jax.random.PRNGKey(0)
    on_tpu = jax.default_backend() == "tpu"

    # pairwise distances (spectral clustering hotspot): n clients
    for n in (128, 512):
        x = jax.random.normal(key, (n, 16))
        us_ref = _time(jax.jit(ref.pairwise_sq_dists_ref), x, x)
        csv_rows.append((f"kernel/pairwise_ref/n{n}", us_ref,
                         f"bytes={n*n*4}"))
        if on_tpu:
            us_k = _time(lambda a, b: ops.pairwise_sq_dists(a, b), x, x)
            csv_rows.append((f"kernel/pairwise_pallas/n{n}", us_k, ""))
            z = x[:n // 8]
            us_c = _time(lambda a, b: ops.rbf_cross_affinity(a, b, 0.5),
                         x, z)
            csv_rows.append((f"kernel/cross_rbf_pallas/n{n}", us_c, ""))

    _bench_spectral_selection(csv_rows, key)
    _bench_cohort(csv_rows, key)
    _bench_fused(csv_rows, key)

    # flash attention jnp-blocked vs naive at growing S
    from repro.models.attention import blocked_attention
    for S in (256, 1024):
        q = jax.random.normal(key, (1, S, 4, 64), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, S, 2, 64))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, S, 2, 64))
        us_naive = _time(jax.jit(lambda a, b, c: ref.attention_ref(
            a, b, c, causal=True)), q, k, v)
        us_block = _time(jax.jit(lambda a, b, c: blocked_attention(
            a, b, c, causal=True)), q, k, v)
        csv_rows.append((f"kernel/attn_naive/S{S}", us_naive, ""))
        csv_rows.append((f"kernel/attn_blocked/S{S}", us_block,
                         f"vs_naive={us_block/us_naive:.2f}x"))

    # SSD chunked vs per-token scan cost proxy
    from repro.models import mamba as M
    from repro.configs import get_config
    cfg = get_config("mamba2-2.7b").reduced()
    p = M.mamba_init(key, cfg)
    x = jax.random.normal(key, (2, 128, cfg.d_model))
    us_ssd = _time(jax.jit(lambda a: M.mamba_apply(p, a, cfg)[0]), x)
    csv_rows.append(("kernel/ssd_chunked/S128", us_ssd, ""))


def main() -> None:
    """Standalone fused-pipeline sweep + CI gate (see module docstring)."""
    import argparse

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--small", action="store_true",
                    help="CI-sized fused sweep (n=4096, m=256); does not "
                         "rewrite BENCH_cohort.json")
    ap.add_argument("--check", action="store_true",
                    help="fail unless fused==unfused (partition + leading "
                         "evals) and bf16/int8 hold the purity floor")
    args = ap.parse_args()
    csv_rows: list = []
    _bench_fused(csv_rows, jax.random.PRNGKey(0), small=args.small,
                 check=args.check)
    for name, us, note in csv_rows:
        print(f"{name},{us:.0f},{note}")


if __name__ == "__main__":
    main()
