"""Benchmark registry — one module per paper table/figure + system perf.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract).

  PYTHONPATH=src python -m benchmarks.run                # everything
  PYTHONPATH=src python -m benchmarks.run --only table2  # one suite
  REPRO_BENCH_SCALE=full ... --only table2               # paper-scale FL
  PYTHONPATH=src python -m benchmarks.run --suite realism --small --check

Suites:
  table2    — paper Table 2: rounds-to-accuracy per selection policy
  table3    — paper Table 3: evaluation criteria of DQRE-SCnet
  fig6      — paper Fig. 6: accuracy-vs-round curves
  kernels   — Pallas/jnp kernel micro-benchmarks
  serve     — concurrent cohort serving: serialized vs coalesced selects
  roofline  — §Roofline baseline table from the dry-run artifacts
  realism   — client-realism scenarios: policies under availability /
              straggler / dropout / churn chaos (emits BENCH_fed.json)
"""

from __future__ import annotations

import argparse
import sys
import time


SUITES = ["table2", "table3", "fig6", "kernels", "serve", "roofline",
          "realism"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", "--suite", dest="only", default=None,
                    help=f"comma-separated subset of {SUITES}")
    ap.add_argument("--small", action="store_true",
                    help="CI-sized realism suite (gated scenarios only)")
    ap.add_argument("--check", action="store_true",
                    help="fail if the realism suite's DQN-vs-stratified "
                         "gate does not hold")
    ap.add_argument("--max-rounds", type=int, default=None,
                    help="cap FL rounds per realism run (wiring smoke; "
                         "the gate expects the default budget)")
    args = ap.parse_args()
    selected = args.only.split(",") if args.only else SUITES

    csv_rows: list = []
    t0 = time.time()
    for suite in selected:
        if suite == "table2":
            from benchmarks import table2_rounds
            table2_rounds.run(csv_rows)
        elif suite == "table3":
            from benchmarks import table3_metrics
            table3_metrics.run(csv_rows)
        elif suite == "fig6":
            from benchmarks import fig6_curves
            fig6_curves.run(csv_rows)
        elif suite == "kernels":
            from benchmarks import kernel_bench
            kernel_bench.run(csv_rows)
        elif suite == "serve":
            from benchmarks import serve_bench
            serve_bench.run(csv_rows)
        elif suite == "roofline":
            from benchmarks import roofline_table
            roofline_table.run(csv_rows)
        elif suite == "realism":
            from benchmarks import realism_bench
            summary = realism_bench.run(csv_rows, small=args.small,
                                        max_rounds=args.max_rounds)
            if args.check and realism_bench.check(summary):
                raise SystemExit(1)
        else:
            print(f"unknown suite {suite!r}", file=sys.stderr)
            raise SystemExit(2)

    print("name,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"# total {time.time()-t0:.1f}s, {len(csv_rows)} rows",
          file=sys.stderr)


if __name__ == "__main__":
    main()
