"""Concurrent cohort-serving benchmark: serialized vs coalesced selects.

Measures end-to-end ``select_cohort`` throughput (selects/sec) on one
embedding-table version at 1/4/16 concurrent clients per tenant × 1/4
tenants (each tenant serves its own model family's client population,
so concurrency scales per shard), two ways:

* **serialized** — the PR 3 path: every thread calls
  ``CohortServer.select_cohort`` directly, so requests queue one at a
  time behind the engine lock and each pays its own fingerprint hash,
  cached-result copy, pool build, and draw.
* **batched** — the ``CohortFrontend`` coalescing path: concurrent
  same-version requests ride one ``select_cohorts`` batch, amortizing
  all of the above over the whole batch.

The **streaming** suite measures the regime the coalescing sweep holds
fixed: continuous embedding churn.  A writer thread updates small row
deltas nonstop while selects run, three ways — no churn at all
(baseline: every select is a fingerprint-cache replay), churn against
the plain inline server (every select pays a solve), and churn against
the double-buffered streaming server (``repro.streaming``: a
``BackgroundSolver`` warms the next version off the select path, so
selects swap in finished results and never solve inline after
warm-up).  Reported as p50/p99 select latency per phase; the
acceptance gate is streaming p99 within 1.5x of the no-churn baseline
with zero forced-inline solves after warm-up.

The **lm** suite benchmarks the continuous-batching LM decode engine
(``launch.serve.DecodeScheduler``) on a reduced config: tokens/sec
under uniform prompt lengths, mixed prompt lengths (continuous vs an
emulation of the retired static-lockstep loop — fixed waves, every
wave decoding to its longest request), and Poisson arrivals streaming
through ``submit``/``step``.  Throughput counts only *useful* tokens
(the tokens requests actually asked for), so the static path is
charged for its padded lockstep steps.  ``--check`` additionally gates
(a) batch-1-oracle equality of a mixed greedy batch and (b) continuous
>= static tokens/sec under mixed lengths.

Emits ``BENCH_serve.json`` (machine-readable sweep) next to the CSV
rows.  The coalescing invariant is checked as it runs: after each
measured phase every tenant's engine must still report exactly one
solve for the (single) table version — everything else was a cache
replay or a coalesced batch member.

  PYTHONPATH=src python benchmarks/serve_bench.py            # full sweep
  PYTHONPATH=src python benchmarks/serve_bench.py --small    # CI-sized
"""

from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

CONCURRENCY = (1, 4, 16)
TENANTS = (1, 4)


def _make_table(n: int, d: int, k: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 6
    labels = rng.integers(0, k, n)
    return (centers[labels]
            + rng.normal(size=(n, d))).astype(np.float32)


def _drive(select_one, tenant_names, concurrency: int, iters: int) -> float:
    """Fire ``concurrency`` workers PER TENANT, each issuing ``iters``
    selects against its tenant; returns total selects/sec."""
    total = concurrency * len(tenant_names)
    barrier = threading.Barrier(total + 1)

    def worker(w):
        name = tenant_names[w % len(tenant_names)]
        barrier.wait()
        for _ in range(iters):
            select_one(name)

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(total)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    return total * iters / max(dt, 1e-9)


def bench_point(num_tenants: int, concurrency: int, *, num_clients: int,
                cohort_size: int, iters: int, seed: int = 0) -> dict:
    from repro.cohort import CohortConfig
    from repro.launch.frontend import make_demo_frontend
    from repro.launch.serve import CohortServer

    k, d = 8, 8
    cfg = CohortConfig(num_clusters=k)
    tables = {i: _make_table(num_clients, d, k, seed + i)
              for i in range(num_tenants)}

    # -- serialized: bare CohortServers, one per tenant ------------------
    servers = {f"family-{i}": CohortServer(num_clients, d, seed=seed + i,
                                           config=CohortConfig(num_clusters=k))
               for i in range(num_tenants)}
    for i, (name, srv) in enumerate(servers.items()):
        srv.update_embeddings(np.arange(num_clients), tables[i])
        srv.select_cohort(cohort_size)            # cold solve out of band
    names = list(servers)
    ser_sps = _drive(lambda nm: servers[nm].select_cohort(cohort_size),
                     names, concurrency, iters)
    for srv in servers.values():
        assert srv.engine.stats["solves"] == 1, srv.engine.stats

    # -- batched: the coalescing frontend --------------------------------
    fe = make_demo_frontend(num_tenants, num_clients, d, config=cfg,
                            seed=seed)
    for i, name in enumerate(fe.tenant_names):
        fe.update_embeddings(name, np.arange(num_clients), tables[i])
        fe.select_cohort(name, cohort_size)       # cold solve out of band
    bat_sps = _drive(lambda nm: fe.select_cohort(nm, cohort_size),
                     fe.tenant_names, concurrency, iters)
    for name in fe.tenant_names:
        assert fe.tenant(name).engine.stats["solves"] == 1, \
            fe.tenant(name).engine.stats
    agg = fe.stats()["frontend"]

    return {"tenants": num_tenants, "concurrency": concurrency,
            "workers_total": concurrency * num_tenants,
            "num_clients": num_clients, "cohort_size": cohort_size,
            "iters_per_worker": iters,
            "serialized_sps": ser_sps, "batched_sps": bat_sps,
            "speedup": bat_sps / ser_sps,
            "batch_factor": agg["batch_factor"],
            "one_solve_per_tenant_version": True}


def _percentiles(lat: list) -> dict:
    arr = np.asarray(lat)
    return {"p50_s": float(np.percentile(arr, 50)),
            "p99_s": float(np.percentile(arr, 99)),
            "mean_s": float(arr.mean()), "samples": len(lat)}


def bench_streaming(*, num_clients: int, cohort_size: int, iters: int,
                    seed: int = 0) -> dict:
    """p50/p99 select latency under continuous embedding churn.

    Three phases on the same workload: **baseline** (static table —
    every select replays the fingerprint cache), **churn_inline** (a
    writer thread churns row deltas against the plain server, so every
    select pays an inline solve), **churn_streaming** (same churn
    against a ``StreamingSpec`` server — selects swap in
    background-warmed results).  ``method="nystrom"`` is pinned so the
    small CI table doesn't fall onto the dense eigh path and time out.
    """
    from repro.cohort import CohortConfig
    from repro.launch.serve import CohortServer
    from repro.streaming import StreamingSpec

    k, d = 8, 8
    delta_rows = 64
    cfg = CohortConfig(num_clusters=k, method="nystrom")
    table = _make_table(num_clients, d, k, seed)
    lat_iters = iters * 5

    def measure(srv) -> list:
        lat = []
        for _ in range(lat_iters):
            t0 = time.perf_counter()
            srv.select_cohort(cohort_size)
            lat.append(time.perf_counter() - t0)
        return lat

    def churn(srv, stop, rng):
        while not stop.is_set():
            ids = rng.integers(0, num_clients, delta_rows)
            rows = (table[ids]
                    + 0.01 * rng.normal(size=(delta_rows, d))
                    ).astype(np.float32)
            srv.update_embeddings(ids, rows)
            time.sleep(0.001)

    def churned_phase(srv) -> list:
        stop = threading.Event()
        writer = threading.Thread(
            target=churn, args=(srv, stop, np.random.default_rng(seed + 1)))
        writer.start()
        try:
            return measure(srv)
        finally:
            stop.set()
            writer.join()

    # -- baseline: static table, cache replays ---------------------------
    base_srv = CohortServer(num_clients, d, seed=seed, config=cfg)
    base_srv.update_embeddings(np.arange(num_clients), table)
    base_srv.select_cohort(cohort_size)           # cold solve out of band
    baseline = measure(base_srv)

    # -- churn against the plain inline server ---------------------------
    inline_srv = CohortServer(num_clients, d, seed=seed, config=cfg)
    inline_srv.update_embeddings(np.arange(num_clients), table)
    inline_srv.select_cohort(cohort_size)
    churn_inline = churned_phase(inline_srv)

    # -- churn against the streaming double-buffer ------------------------
    stream_srv = CohortServer(num_clients, d, seed=seed, config=cfg,
                              streaming=StreamingSpec())
    stream_srv.update_embeddings(np.arange(num_clients), table)
    stream_srv.select_cohort(cohort_size)         # warm-up (forced inline)
    deadline = time.perf_counter() + 60
    while (stream_srv.stats()["warm_ahead"] < 1
           and time.perf_counter() < deadline):
        time.sleep(0.005)
    inline_before = stream_srv.stats()["forced_inline"]
    churn_streaming = churned_phase(stream_srv)
    st = stream_srv.stats()
    stream_srv.close()

    rec = {
        "suite": "streaming", "num_clients": num_clients,
        "cohort_size": cohort_size, "delta_rows": delta_rows,
        "phases": {"baseline": _percentiles(baseline),
                   "churn_inline": _percentiles(churn_inline),
                   "churn_streaming": _percentiles(churn_streaming)},
        "forced_inline_after_warmup": st["forced_inline"] - inline_before,
        "warm_ahead": st["warm_ahead"],
        "served_warm": st["served_warm"],
    }
    rec["p99_ratio_vs_baseline"] = (
        rec["phases"]["churn_streaming"]["p99_s"]
        / max(rec["phases"]["baseline"]["p99_s"], 1e-9))
    rec["p99_ratio_inline_vs_baseline"] = (
        rec["phases"]["churn_inline"]["p99_s"]
        / max(rec["phases"]["baseline"]["p99_s"], 1e-9))
    return rec


def _lm_requests(cfg, count: int, prompt_max: int, gen_max: int, *,
                 mixed: bool, seed: int) -> list:
    from repro.launch.serve import Request
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(count):
        plen = int(rng.integers(1, prompt_max + 1)) if mixed else prompt_max
        gen = int(rng.integers(1, gen_max + 1)) if mixed else gen_max
        reqs.append(Request(i, rng.integers(0, cfg.vocab_size,
                                            plen).astype(np.int32), gen))
    return reqs


def _clone(reqs) -> list:
    from repro.launch.serve import Request
    return [Request(r.uid, r.prompt, r.max_new_tokens) for r in reqs]


def _lm_continuous(cfg, reqs, batch: int, max_seq: int, seed: int) -> dict:
    """Useful tokens/sec through the continuous scheduler (R requests
    flow through `batch` slots with admit/retire)."""
    from repro.launch.serve import Server
    srv = Server(cfg, batch, max_seq, seed=seed)
    srv.serve_batch(_clone(reqs))                 # jit warm-up pass
    t0 = time.perf_counter()
    done = srv.serve_batch(_clone(reqs))
    dt = time.perf_counter() - t0
    useful = sum(len(r.generated) for r in done)
    return {"tok_s": useful / max(dt, 1e-9), "useful_tokens": useful,
            "wall_s": dt, "decode_steps": srv.stats()["decode_steps"] // 2}


def _lm_static(cfg, reqs, batch: int, max_seq: int, seed: int) -> dict:
    """Emulate the retired lockstep loop: fixed waves of ``batch``
    requests, every wave decoding for its LONGEST request (short
    requests ride along producing throwaway tokens), and the next wave
    blocked until the whole wave finishes.  Only the originally
    requested tokens count as useful."""
    from repro.launch.serve import Server
    srv = Server(cfg, batch, max_seq, seed=seed)

    def one_pass():
        for i in range(0, len(reqs), batch):
            wave = reqs[i:i + batch]
            steps = max(r.max_new_tokens for r in wave)
            padded = _clone(wave)
            for r in padded:
                r.max_new_tokens = steps          # lockstep: all run max
            srv.serve_batch(padded)

    one_pass()                                    # jit warm-up pass
    t0 = time.perf_counter()
    one_pass()
    dt = time.perf_counter() - t0
    useful = sum(r.max_new_tokens for r in reqs)
    return {"tok_s": useful / max(dt, 1e-9), "useful_tokens": useful,
            "wall_s": dt}


def _lm_poisson(cfg, reqs, batch: int, max_seq: int, seed: int,
                rate_per_s: float) -> dict:
    """Stream requests through submit/step with Poisson inter-arrival
    gaps; the scheduler admits each the moment a slot frees up."""
    from repro.launch.serve import Server
    srv = Server(cfg, batch, max_seq, seed=seed)
    srv.serve_batch(_clone(reqs[:batch]))         # jit warm-up pass
    rng = np.random.default_rng(seed + 17)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_per_s, len(reqs)))
    pending = _clone(reqs)
    done = []
    i = 0
    t0 = time.perf_counter()
    while len(done) < len(reqs):
        now = time.perf_counter() - t0
        while i < len(pending) and arrivals[i] <= now:
            srv.submit(pending[i])
            i += 1
        worked = srv.scheduler.step()
        done.extend(srv.scheduler.completed())
        if not worked and i < len(pending):
            time.sleep(max(0.0, arrivals[i] - (time.perf_counter() - t0)))
    dt = time.perf_counter() - t0
    useful = sum(len(r.generated) for r in done)
    return {"tok_s": useful / max(dt, 1e-9), "useful_tokens": useful,
            "makespan_s": dt, "rate_per_s": rate_per_s}


def bench_lm(*, small: bool, seed: int = 0) -> dict:
    """Continuous-batching LM decode suite on a reduced config."""
    from repro.configs import get_config
    from repro.launch.serve import Server

    cfg = get_config("qwen2-7b").reduced()
    if small:
        batch, count, prompt_max, gen_max = 2, 6, 12, 8
    else:
        batch, count, prompt_max, gen_max = 4, 16, 24, 16
    max_seq = prompt_max + gen_max                # no truncation either path

    uniform = _lm_requests(cfg, count, prompt_max, gen_max, mixed=False,
                           seed=seed)
    mixed = _lm_requests(cfg, count, prompt_max, gen_max, mixed=True,
                         seed=seed + 1)

    rec = {
        "suite": "lm_decode", "arch": cfg.name, "reduced": True,
        "batch": batch, "requests": count, "max_seq": max_seq,
        "prompt_max": prompt_max, "gen_max": gen_max,
        "uniform": {"continuous": _lm_continuous(cfg, uniform, batch,
                                                 max_seq, seed)},
        "mixed": {"static": _lm_static(cfg, mixed, batch, max_seq, seed),
                  "continuous": _lm_continuous(cfg, mixed, batch, max_seq,
                                               seed)},
        "poisson": _lm_poisson(cfg, mixed, batch, max_seq, seed,
                               rate_per_s=200.0),
    }
    rec["mixed"]["speedup"] = (rec["mixed"]["continuous"]["tok_s"]
                               / max(rec["mixed"]["static"]["tok_s"], 1e-9))

    # oracle: every mixed greedy continuation == the request decoded alone
    batched = Server(cfg, batch, max_seq, seed=seed)
    got = {r.uid: r.generated for r in batched.serve_batch(_clone(mixed))}
    exact = True
    for r in mixed:
        solo = Server(cfg, 1, max_seq, seed=seed)
        want = solo.serve_batch(_clone([r]))[0].generated
        exact = exact and got[r.uid] == want
    rec["oracle_exact"] = exact
    return rec


def run(csv_rows: list, *, num_clients: int = 20_000, cohort_size: int = 64,
        iters: int = 20, small: bool = False,
        out: str = "BENCH_serve.json") -> list:
    records = []
    for num_tenants in TENANTS:
        for concurrency in CONCURRENCY:
            rec = bench_point(num_tenants, concurrency,
                              num_clients=num_clients,
                              cohort_size=cohort_size, iters=iters)
            records.append(rec)
            csv_rows.append(
                (f"serve/t{num_tenants}/c{concurrency}/serialized",
                 1e6 / rec["serialized_sps"],
                 f"selects_per_sec={rec['serialized_sps']:.1f}"))
            csv_rows.append(
                (f"serve/t{num_tenants}/c{concurrency}/batched",
                 1e6 / rec["batched_sps"],
                 f"selects_per_sec={rec['batched_sps']:.1f} "
                 f"speedup={rec['speedup']:.2f}x"))
            print(f"tenants={num_tenants} concurrency={concurrency}: "
                  f"serialized {rec['serialized_sps']:,.1f} selects/s, "
                  f"batched {rec['batched_sps']:,.1f} selects/s "
                  f"({rec['speedup']:.2f}x, batch factor "
                  f"{rec['batch_factor']:.2f})")
    streaming = bench_streaming(num_clients=num_clients,
                                cohort_size=cohort_size, iters=iters)
    for phase, pct in streaming["phases"].items():
        csv_rows.append((f"serve/streaming/{phase}",
                         1e6 * pct["p99_s"],
                         f"p50_us={1e6 * pct['p50_s']:.0f} "
                         f"p99_us={1e6 * pct['p99_s']:.0f}"))
    print(f"streaming churn: baseline p99 "
          f"{1e6 * streaming['phases']['baseline']['p99_s']:.0f}us, "
          f"inline p99 "
          f"{1e6 * streaming['phases']['churn_inline']['p99_s']:.0f}us, "
          f"streaming p99 "
          f"{1e6 * streaming['phases']['churn_streaming']['p99_s']:.0f}us "
          f"({streaming['p99_ratio_vs_baseline']:.2f}x baseline, "
          f"{streaming['forced_inline_after_warmup']} inline solves "
          f"after warm-up)")
    lm = bench_lm(small=small)
    csv_rows.append(("serve/lm/uniform/continuous",
                     1e6 / lm["uniform"]["continuous"]["tok_s"],
                     f"tok_s={lm['uniform']['continuous']['tok_s']:.1f}"))
    csv_rows.append(("serve/lm/mixed/static",
                     1e6 / lm["mixed"]["static"]["tok_s"],
                     f"tok_s={lm['mixed']['static']['tok_s']:.1f}"))
    csv_rows.append(("serve/lm/mixed/continuous",
                     1e6 / lm["mixed"]["continuous"]["tok_s"],
                     f"tok_s={lm['mixed']['continuous']['tok_s']:.1f} "
                     f"speedup={lm['mixed']['speedup']:.2f}x"))
    csv_rows.append(("serve/lm/poisson/continuous",
                     1e6 / lm["poisson"]["tok_s"],
                     f"tok_s={lm['poisson']['tok_s']:.1f}"))
    print(f"lm decode ({lm['arch']} reduced, batch={lm['batch']}, "
          f"{lm['requests']} reqs): uniform "
          f"{lm['uniform']['continuous']['tok_s']:,.1f} tok/s; mixed "
          f"static {lm['mixed']['static']['tok_s']:,.1f} vs continuous "
          f"{lm['mixed']['continuous']['tok_s']:,.1f} tok/s "
          f"({lm['mixed']['speedup']:.2f}x); poisson "
          f"{lm['poisson']['tok_s']:,.1f} tok/s; oracle_exact="
          f"{lm['oracle_exact']}")
    with open(out, "w") as fh:
        json.dump({"unit": "selects_per_sec", "records": records,
                   "streaming": streaming, "lm_decode": lm}, fh, indent=2)
    return records, streaming, lm


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=20_000)
    ap.add_argument("--cohort-size", type=int, default=64)
    ap.add_argument("--iters", type=int, default=20,
                    help="selects per worker per measured point")
    ap.add_argument("--small", action="store_true",
                    help="CI-sized run: 2000 clients, 8 iters")
    ap.add_argument("--check", action="store_true",
                    help="fail unless batched >= 1.5x serialized at 16 "
                         "concurrent clients (CI smoke; the full-size "
                         "sweep targets >= 3x)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.small:
        args.clients, args.iters = 2000, 8

    rows: list = []
    records, streaming, lm = run(rows, num_clients=args.clients,
                                 cohort_size=args.cohort_size,
                                 iters=args.iters, small=args.small,
                                 out=args.out)
    if args.check:
        worst = min(r["speedup"] for r in records
                    if r["concurrency"] == max(CONCURRENCY))
        if worst < 1.5:
            print(f"FAIL: batched speedup {worst:.2f}x < 1.5x at "
                  f"{max(CONCURRENCY)} concurrent clients")
            return 1
        print(f"ok: batched >= {worst:.2f}x serialized at "
              f"{max(CONCURRENCY)} concurrent clients")
        if streaming["forced_inline_after_warmup"] != 0:
            print(f"FAIL: {streaming['forced_inline_after_warmup']} "
                  f"inline solves after streaming warm-up (expected 0)")
            return 1
        # small-N CI boxes are noisy: allow 5ms absolute grace on top of
        # the 1.5x relative gate the full-size sweep targets
        p99_base = streaming["phases"]["baseline"]["p99_s"]
        p99_stream = streaming["phases"]["churn_streaming"]["p99_s"]
        if p99_stream > 1.5 * p99_base + 0.005:
            print(f"FAIL: streaming p99 {p99_stream * 1e6:.0f}us under "
                  f"churn exceeds 1.5x no-churn baseline "
                  f"({p99_base * 1e6:.0f}us) + 5ms grace")
            return 1
        print(f"ok: streaming p99 under churn "
              f"{streaming['p99_ratio_vs_baseline']:.2f}x baseline, "
              f"0 inline solves after warm-up")
        if not lm["oracle_exact"]:
            print("FAIL: mixed-length greedy batch diverged from the "
                  "batch-1 oracle")
            return 1
        if lm["mixed"]["speedup"] < 1.0:
            print(f"FAIL: continuous batching {lm['mixed']['speedup']:.2f}x "
                  f"static under mixed prompt lengths (expected >= 1.0x)")
            return 1
        print(f"ok: lm decode oracle exact; continuous "
              f"{lm['mixed']['speedup']:.2f}x static under mixed lengths")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
