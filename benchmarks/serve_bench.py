"""Concurrent cohort-serving benchmark: serialized vs coalesced selects.

Measures end-to-end ``select_cohort`` throughput (selects/sec) on one
embedding-table version at 1/4/16 concurrent clients per tenant × 1/4
tenants (each tenant serves its own model family's client population,
so concurrency scales per shard), two ways:

* **serialized** — the PR 3 path: every thread calls
  ``CohortServer.select_cohort`` directly, so requests queue one at a
  time behind the engine lock and each pays its own fingerprint hash,
  cached-result copy, pool build, and draw.
* **batched** — the ``CohortFrontend`` coalescing path: concurrent
  same-version requests ride one ``select_cohorts`` batch, amortizing
  all of the above over the whole batch.

Emits ``BENCH_serve.json`` (machine-readable sweep) next to the CSV
rows.  The coalescing invariant is checked as it runs: after each
measured phase every tenant's engine must still report exactly one
solve for the (single) table version — everything else was a cache
replay or a coalesced batch member.

  PYTHONPATH=src python benchmarks/serve_bench.py            # full sweep
  PYTHONPATH=src python benchmarks/serve_bench.py --small    # CI-sized
"""

from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

CONCURRENCY = (1, 4, 16)
TENANTS = (1, 4)


def _make_table(n: int, d: int, k: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * 6
    labels = rng.integers(0, k, n)
    return (centers[labels]
            + rng.normal(size=(n, d))).astype(np.float32)


def _drive(select_one, tenant_names, concurrency: int, iters: int) -> float:
    """Fire ``concurrency`` workers PER TENANT, each issuing ``iters``
    selects against its tenant; returns total selects/sec."""
    total = concurrency * len(tenant_names)
    barrier = threading.Barrier(total + 1)

    def worker(w):
        name = tenant_names[w % len(tenant_names)]
        barrier.wait()
        for _ in range(iters):
            select_one(name)

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(total)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    return total * iters / max(dt, 1e-9)


def bench_point(num_tenants: int, concurrency: int, *, num_clients: int,
                cohort_size: int, iters: int, seed: int = 0) -> dict:
    from repro.cohort import CohortConfig
    from repro.launch.frontend import make_demo_frontend
    from repro.launch.serve import CohortServer

    k, d = 8, 8
    cfg = CohortConfig(num_clusters=k)
    tables = {i: _make_table(num_clients, d, k, seed + i)
              for i in range(num_tenants)}

    # -- serialized: bare CohortServers, one per tenant ------------------
    servers = {f"family-{i}": CohortServer(num_clients, d, seed=seed + i,
                                           config=CohortConfig(num_clusters=k))
               for i in range(num_tenants)}
    for i, (name, srv) in enumerate(servers.items()):
        srv.update_embeddings(np.arange(num_clients), tables[i])
        srv.select_cohort(cohort_size)            # cold solve out of band
    names = list(servers)
    ser_sps = _drive(lambda nm: servers[nm].select_cohort(cohort_size),
                     names, concurrency, iters)
    for srv in servers.values():
        assert srv.engine.stats["solves"] == 1, srv.engine.stats

    # -- batched: the coalescing frontend --------------------------------
    fe = make_demo_frontend(num_tenants, num_clients, d, config=cfg,
                            seed=seed)
    for i, name in enumerate(fe.tenant_names):
        fe.update_embeddings(name, np.arange(num_clients), tables[i])
        fe.select_cohort(name, cohort_size)       # cold solve out of band
    bat_sps = _drive(lambda nm: fe.select_cohort(nm, cohort_size),
                     fe.tenant_names, concurrency, iters)
    for name in fe.tenant_names:
        assert fe.tenant(name).engine.stats["solves"] == 1, \
            fe.tenant(name).engine.stats
    agg = fe.stats()["frontend"]

    return {"tenants": num_tenants, "concurrency": concurrency,
            "workers_total": concurrency * num_tenants,
            "num_clients": num_clients, "cohort_size": cohort_size,
            "iters_per_worker": iters,
            "serialized_sps": ser_sps, "batched_sps": bat_sps,
            "speedup": bat_sps / ser_sps,
            "batch_factor": agg["batch_factor"],
            "one_solve_per_tenant_version": True}


def run(csv_rows: list, *, num_clients: int = 20_000, cohort_size: int = 64,
        iters: int = 20, out: str = "BENCH_serve.json") -> list:
    records = []
    for num_tenants in TENANTS:
        for concurrency in CONCURRENCY:
            rec = bench_point(num_tenants, concurrency,
                              num_clients=num_clients,
                              cohort_size=cohort_size, iters=iters)
            records.append(rec)
            csv_rows.append(
                (f"serve/t{num_tenants}/c{concurrency}/serialized",
                 1e6 / rec["serialized_sps"],
                 f"selects_per_sec={rec['serialized_sps']:.1f}"))
            csv_rows.append(
                (f"serve/t{num_tenants}/c{concurrency}/batched",
                 1e6 / rec["batched_sps"],
                 f"selects_per_sec={rec['batched_sps']:.1f} "
                 f"speedup={rec['speedup']:.2f}x"))
            print(f"tenants={num_tenants} concurrency={concurrency}: "
                  f"serialized {rec['serialized_sps']:,.1f} selects/s, "
                  f"batched {rec['batched_sps']:,.1f} selects/s "
                  f"({rec['speedup']:.2f}x, batch factor "
                  f"{rec['batch_factor']:.2f})")
    with open(out, "w") as fh:
        json.dump({"unit": "selects_per_sec", "records": records}, fh,
                  indent=2)
    return records


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=20_000)
    ap.add_argument("--cohort-size", type=int, default=64)
    ap.add_argument("--iters", type=int, default=20,
                    help="selects per worker per measured point")
    ap.add_argument("--small", action="store_true",
                    help="CI-sized run: 2000 clients, 8 iters")
    ap.add_argument("--check", action="store_true",
                    help="fail unless batched >= 1.5x serialized at 16 "
                         "concurrent clients (CI smoke; the full-size "
                         "sweep targets >= 3x)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.small:
        args.clients, args.iters = 2000, 8

    rows: list = []
    records = run(rows, num_clients=args.clients,
                  cohort_size=args.cohort_size, iters=args.iters,
                  out=args.out)
    if args.check:
        worst = min(r["speedup"] for r in records
                    if r["concurrency"] == max(CONCURRENCY))
        if worst < 1.5:
            print(f"FAIL: batched speedup {worst:.2f}x < 1.5x at "
                  f"{max(CONCURRENCY)} concurrent clients")
            return 1
        print(f"ok: batched >= {worst:.2f}x serialized at "
              f"{max(CONCURRENCY)} concurrent clients")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
