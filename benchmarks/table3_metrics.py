"""Paper Table 3: evaluation criteria of DQRE-SCnet per dataset
(balanced accuracy, accuracy, recall, kappa, precision, AUC)."""

from __future__ import annotations

import time

from benchmarks.fl_common import run_policy

DATASETS = ["mnist", "fashion_mnist", "cifar10"]


def run(csv_rows: list) -> None:
    for dataset in DATASETS:
        t0 = time.time()
        runner = run_policy(dataset, "dqre_sc", sigma=1.0)
        m = runner.final_metrics()
        us = (time.time() - t0) * 1e6
        derived = ";".join(f"{k}={v:.4f}" for k, v in m.items())
        csv_rows.append((f"table3/{dataset}/dqre_sc", us, derived))
