"""Client-realism scenario suite: selection policies under system chaos.

The paper's Table 2 compares policies in an *ideal* simulation — every
selected client responds instantly.  This suite re-runs the comparison
under the fault-injection layer (``repro.fed.realism``): IID / non-IID
data skew crossed with five system-heterogeneity scenarios —

  none        benign trace (realism plumbing on, failure modes off)
  diurnal     half the population availability-phased a half-day apart
  stragglers  a label-correlated slow tier that always misses the
              round deadline (the server eats the full deadline wait)
  dropout     a label-correlated flaky group with a mid-round hazard
  churn       clients leave/rejoin the population between rounds

Failure groups are **correlated with data heterogeneity** (each
client's majority label), so under non-IID skew they align with the
embedding clusters Algorithm I finds — which is exactly what gives the
cluster-level DQN something to learn: avoid the slow/flaky clusters,
keep the accuracy signal.  Stratified round-robin, by construction,
keeps spending cohort slots on them and pays the deadline wait every
round.  The headline metric is therefore **simulated wall-clock to
target accuracy** (``FederatedRunner.sim_seconds_to_accuracy``), not
just rounds.

Everything is deterministic given the seed: traces draw from
``SeedSequence([seed, stream, round])`` and all round timings go
through the runner's ``SimClock``, so ``--check`` gates on exact
replays, not noisy wall time.  Emits ``BENCH_fed.json``.

  PYTHONPATH=src python -m benchmarks.realism_bench           # full grid
  PYTHONPATH=src python -m benchmarks.realism_bench --small --check  # CI
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.fl_common import DEFAULTS, MAX_ROUNDS, TARGETS

SCENARIOS = ("none", "diurnal", "stragglers", "dropout", "churn")
SKEWS = {"iid": 0.0, "noniid": 0.8}
POLICIES = ("stratified", "dqre_sc")
#: scenarios where the label-correlated failure group gives the DQN a
#: learnable system-heterogeneity signal (the --check gate set)
GATED = ("stragglers", "dropout")


def _majority_labels(runner) -> np.ndarray:
    """Per-client majority label — the axis failure groups correlate on."""
    return np.array([
        np.bincount(runner.y_train[s],
                    minlength=runner.spec.num_classes).argmax()
        for s in runner.shards])


def build_trace(scenario: str, runner, seed: int):
    """(ClientTrace, RoundSpec) for one scenario, correlated with the
    runner's own partition: clients whose majority label falls in the
    upper half of the label space form the slow/flaky/phase-shifted
    group, so under non-IID skew the failure modes line up with the
    embedding clusters the policies see."""
    from repro.fed import ClientTrace, RoundSpec, TraceSpec

    n = runner.cfg.num_clients
    flaky = _majority_labels(runner) >= runner.spec.num_classes // 2
    if scenario == "none":
        spec = TraceSpec(latency_jitter=0.05)
        rspec = RoundSpec()
    elif scenario == "diurnal":
        spec = TraceSpec(availability="diurnal", day_period_s=120.0,
                         avail_floor=0.05, avail_amplitude=0.9,
                         phase_assign=tuple(np.where(flaky, 0.5, 0.0)),
                         latency_jitter=0.05)
        rspec = RoundSpec(reward_blend=0.5)
    elif scenario == "stragglers":
        spec = TraceSpec(tiers=(1.0, 12.0),
                         tier_assign=tuple(flaky.astype(int)),
                         base_latency_s=1.0, latency_jitter=0.1)
        # the slow tier's ~12s latency can never beat the 5s deadline:
        # every slot spent on it is a dropped client + a full 5s wait
        rspec = RoundSpec(deadline_s=5.0, reward_blend=0.5)
    elif scenario == "dropout":
        # flaky-group hazard 0.6*5 = 3.0 over a ~1s exposure: a flaky
        # pick drops with p ~ 1-exp(-3) ~ 0.95 — the slot is wasted
        # almost every time, so avoiding the cluster is worth rounds
        spec = TraceSpec(dropout_hazard=0.6,
                         hazard_assign=tuple(np.where(flaky, 5.0, 0.05)),
                         latency_jitter=0.1)
        rspec = RoundSpec(reward_blend=0.5)
    elif scenario == "churn":
        spec = TraceSpec(p_leave=0.15, p_join=0.3, latency_jitter=0.05)
        rspec = RoundSpec(reward_blend=0.25)
    else:
        raise ValueError(f"unknown scenario {scenario!r}")
    return ClientTrace(n, spec, seed=seed), rspec


def run_one(dataset: str, policy: str, scenario: str, skew: str,
            seed: int = 0, max_rounds: int = None) -> dict:
    from repro.fed import FederatedRunner, RunnerConfig

    cfg = RunnerConfig(dataset=dataset, policy=policy, sigma=SKEWS[skew],
                       target_accuracy=TARGETS[dataset], seed=seed,
                       # fast exploration decay: the quick-scale runs are
                       # short, so the DQN must commit to what it learned
                       # about slow/flaky clusters within a few rounds
                       eps_decay_steps=5,
                       **DEFAULTS)
    runner = FederatedRunner(cfg)
    trace, rspec = build_trace(scenario, runner, seed)
    runner.attach_trace(trace, rspec)
    runner.run(max_rounds or MAX_ROUNDS, stop_at_target=True)
    hist = runner.history
    return {
        "dataset": dataset, "scenario": scenario, "skew": skew,
        "policy": policy, "seed": seed,
        "rounds_run": len(hist),
        "rounds_to_target": runner.rounds_to_accuracy(),
        "sim_s_to_target": runner.sim_seconds_to_accuracy(),
        "sim_s_total": sum(r.sim_seconds for r in hist),
        "final_accuracy": hist[-1].accuracy,
        "completed_total": int(sum(r.num_completed for r in hist)),
        "dropped_total": int(sum(r.num_dropped for r in hist)),
        "stragglers_total": int(sum(r.num_stragglers for r in hist)),
        "mean_attainment": float(np.mean(
            [r.outcome.attainment for r in hist])),
    }


def _rank_key(rec: dict, max_rounds: int):
    """Orders policies: fewest rounds to target, then least simulated
    wall clock, then (for never-reached runs) highest final accuracy."""
    r, s = rec["rounds_to_target"], rec["sim_s_to_target"]
    return (r if r is not None else max_rounds + 1,
            s if s is not None else float("inf"),
            -rec["final_accuracy"])


def run(csv_rows: list, *, dataset: str = "mnist", seed: int = 0,
        small: bool = False, max_rounds: int = None,
        out: str = "BENCH_fed.json") -> dict:
    max_rounds = max_rounds or MAX_ROUNDS
    skews = ("noniid",) if small else tuple(SKEWS)
    scenarios = GATED if small else SCENARIOS
    records, wins = [], []
    for skew in skews:
        for scenario in scenarios:
            pair = {}
            for policy in POLICIES:
                rec = run_one(dataset, policy, scenario, skew,
                              seed=seed, max_rounds=max_rounds)
                records.append(rec)
                pair[policy] = rec
                rt = rec["rounds_to_target"]
                ss = rec["sim_s_to_target"]
                csv_rows.append((
                    f"realism/{skew}/{scenario}/{policy}",
                    0.0 if ss is None else ss * 1e6,
                    f"rounds_to_target="
                    f"{'never' if rt is None else rt} "
                    f"sim_s={'inf' if ss is None else f'{ss:.1f}'} "
                    f"acc={rec['final_accuracy']:.3f} "
                    f"attainment={rec['mean_attainment']:.2f}"))
                print(f"{skew}/{scenario}/{policy}: "
                      f"rounds={'never' if rt is None else rt} "
                      f"sim_s={'inf' if ss is None else f'{ss:.1f}'} "
                      f"acc={rec['final_accuracy']:.3f} "
                      f"dropped={rec['dropped_total']}")
            dqn, strat = pair["dqre_sc"], pair["stratified"]
            if _rank_key(dqn, max_rounds) < _rank_key(strat, max_rounds):
                wins.append(f"{skew}/{scenario}")
    summary = {
        "unit": "simulated_seconds_to_target",
        "dataset": dataset, "target_accuracy": TARGETS[dataset],
        "max_rounds": max_rounds, "seed": seed, "small": small,
        "defaults": dict(DEFAULTS),
        "dqn_wins": wins,
        "records": records,
    }
    with open(out, "w") as fh:
        json.dump(summary, fh, indent=2)
    print(f"dqre_sc beats stratified under: {wins or 'none'}")
    return summary


def check(summary: dict) -> int:
    """CI gate: on every GATED non-IID scenario the DQN must reach the
    target in no more rounds than stratified — and strictly less
    simulated wall clock when both reach it."""
    max_rounds = summary["max_rounds"]
    by = {(r["skew"], r["scenario"], r["policy"]): r
          for r in summary["records"]}
    failures = []
    for scenario in GATED:
        dqn = by.get(("noniid", scenario, "dqre_sc"))
        strat = by.get(("noniid", scenario, "stratified"))
        if dqn is None or strat is None:
            failures.append(f"{scenario}: gated records missing")
            continue
        rd = dqn["rounds_to_target"] or max_rounds + 1
        rs = strat["rounds_to_target"] or max_rounds + 1
        if rd > rs:
            failures.append(
                f"{scenario}: dqre_sc rounds-to-target {rd} > "
                f"stratified {rs}")
        if (dqn["sim_s_to_target"] is not None
                and strat["sim_s_to_target"] is not None
                and dqn["sim_s_to_target"] >= strat["sim_s_to_target"]):
            failures.append(
                f"{scenario}: dqre_sc sim_s {dqn['sim_s_to_target']:.1f} "
                f">= stratified {strat['sim_s_to_target']:.1f}")
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print(f"ok: dqre_sc <= stratified (rounds) and < (sim wall clock) "
          f"on {', '.join(GATED)}")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="mnist", choices=sorted(TARGETS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-rounds", type=int, default=None)
    ap.add_argument("--small", action="store_true",
                    help="CI-sized run: non-IID skew only, gated "
                         "scenarios only")
    ap.add_argument("--check", action="store_true",
                    help="fail unless dqre_sc reaches the target in "
                         "<= stratified's rounds (and less simulated "
                         "wall clock) on the gated scenarios")
    ap.add_argument("--out", default="BENCH_fed.json")
    args = ap.parse_args()

    rows: list = []
    summary = run(rows, dataset=args.dataset, seed=args.seed,
                  small=args.small, max_rounds=args.max_rounds,
                  out=args.out)
    if args.check:
        return check(summary)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
