"""Paper Fig. 6: accuracy vs communication round, all three datasets.

Writes the full curves to experiments/fl/fig6_<dataset>.csv and reports
summary points in the bench CSV."""

from __future__ import annotations

import os
import time

from benchmarks.fl_common import MAX_ROUNDS, run_policy

DATASETS = ["mnist", "fashion_mnist", "cifar10"]
SIGMA = 0.5


def run(csv_rows: list) -> None:
    os.makedirs("experiments/fl", exist_ok=True)
    for dataset in DATASETS:
        t0 = time.time()
        runner = run_policy(dataset, "dqre_sc", SIGMA,
                            max_rounds=MAX_ROUNDS)
        path = f"experiments/fl/fig6_{dataset}.csv"
        with open(path, "w") as f:
            f.write("round,accuracy,loss,reward\n")
            for h in runner.history:
                f.write(f"{h.round_idx},{h.accuracy:.4f},{h.loss:.4f},"
                        f"{h.reward:.4f}\n")
        us = (time.time() - t0) * 1e6
        accs = [h.accuracy for h in runner.history]
        csv_rows.append((f"fig6/{dataset}", us,
                         f"rounds={len(accs)};first={accs[0]:.3f};"
                         f"best={max(accs):.3f};curve={path}"))
