"""Serve a small LM through the continuous-batching decode engine.

Mixed-length requests flow through the DecodeScheduler's slot table —
admitted via slot-targeted prefill, decoded with per-request cache
positions, retired mid-decode — on a CPU-scale model.  Pass
``--requests`` > ``--batch`` to watch the queue drain through the
slots.

  PYTHONPATH=src python examples/serve_lm.py --batch 4 --requests 10
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests (default: one per slot)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.serve import Request, Server

    cfg = get_config(args.arch).reduced()
    server = Server(cfg, args.batch, args.prompt_len + args.gen_len,
                    temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    rng.integers(4, args.prompt_len))
                    .astype(np.int32), int(rng.integers(1, args.gen_len + 1)))
            for i in range(args.requests or args.batch)]
    t0 = time.time()
    done = server.serve_batch(reqs)
    dt = time.time() - t0
    s = server.stats()
    print(f"served {len(done)} requests in {dt:.1f}s "
          f"({server.last_decode_tok_s:,.1f} decode tok/s; "
          f"{s['decode_steps']} decode steps over {s['slots']} slots)")
    for r in done:
        print(f"  req {r.uid} (prompt {len(r.prompt)} toks) -> "
              f"{r.generated[:8]}...")


if __name__ == "__main__":
    main()
