"""Serve a small LM with batched requests (prefill + decode loop).

Exercises the same serve_step the dry-run lowers for decode_32k /
long_500k, on a CPU-scale model with a batch of concurrent requests.

  PYTHONPATH=src python examples/serve_lm.py --batch 4 --gen-len 16
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.serve import Request, Server

    cfg = get_config(args.arch).reduced()
    server = Server(cfg, args.batch, args.prompt_len + args.gen_len,
                    temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    rng.integers(4, args.prompt_len))
                    .astype(np.int32), args.gen_len)
            for i in range(args.batch)]
    t0 = time.time()
    done = server.serve_batch(reqs)
    dt = time.time() - t0
    print(f"served {len(done)} requests in {dt:.1f}s "
          f"({server.last_decode_tok_s:,.1f} decode tok/s)")
    for r in done:
        print(f"  req {r.uid} (prompt {len(r.prompt)} toks) -> "
              f"{r.generated[:8]}...")


if __name__ == "__main__":
    main()
