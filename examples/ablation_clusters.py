"""Ablation: DQRE-SCnet cluster-count sensitivity + eigengap auto-k.

The paper fixes its cluster count implicitly and mentions the eigengap
heuristic (§3.4) without ablating it.  This driver compares fixed
k ∈ {2, 4, 8} against eigengap-chosen k on one dataset/σ.

  PYTHONPATH=src python examples/ablation_clusters.py --rounds 12
"""

import argparse
import sys

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mnist")
    ap.add_argument("--sigma", type=float, default=0.8)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.fed import FederatedRunner, RunnerConfig

    variants = [("k=2", {"num_clusters": 2}),
                ("k=4", {"num_clusters": 4}),
                ("k=8", {"num_clusters": 8}),
                ("eigengap(<=8)", {"num_clusters": 8, "auto_k": True})]
    for name, kw in variants:
        cfg = RunnerConfig(dataset=args.dataset, policy="dqre_sc",
                           sigma=args.sigma, num_clients=20,
                           clients_per_round=5, local_steps=8,
                           batch_size=16, train_size=2500, eval_size=384,
                           target_accuracy=0.9, seed=args.seed,
                           policy_kwargs=kw)
        runner = FederatedRunner(cfg)
        runner.run(args.rounds, stop_at_target=True)
        rounds = runner.rounds_to_accuracy()
        print(f"{name:15s}: rounds_to_0.90 = "
              f"{rounds if rounds else f'>{args.rounds}'}  "
              f"final = {runner.history[-1].accuracy:.4f}")


if __name__ == "__main__":
    main()
