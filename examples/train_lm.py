"""Train an LM on the synthetic token stream (end-to-end driver).

Default is a CPU-scale model; ``--preset 100m`` trains a ~100M-param
gemma-style model for a few hundred steps (the assignment's end-to-end
driver — budget several hours on this 1-core container; it is the same
code path the dry-run lowers at 256-chip scale).

  PYTHONPATH=src python examples/train_lm.py --steps 100
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import Checkpointer
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.data import TokenDataConfig, make_batch_iterator
    from repro.launch.steps import make_optimizer, make_train_step
    from repro.models import transformer as T

    base = get_config("gemma-2b")
    if args.preset == "tiny":
        cfg = dataclasses.replace(base.reduced(), vocab_size=2048)
    else:
        # ~100M params: 8 layers, d_model 768, GeGLU, 32k vocab
        cfg = dataclasses.replace(
            base, num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=3072, vocab_size=32768,
            param_dtype="float32", compute_dtype="float32")

    shape = ShapeConfig("train", args.seq_len, args.global_batch, "train")
    opt = make_optimizer(cfg, args.steps, state_dtype="float32")
    step_fn = jax.jit(make_train_step(cfg, shape, opt))

    params = T.init_lm(jax.random.PRNGKey(args.seed), cfg)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model} V={cfg.vocab_size})")
    opt_state = opt.init(params)
    data = TokenDataConfig(cfg.vocab_size, args.seq_len, args.global_batch,
                           seed=args.seed)
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    t0 = time.time()
    for step, batch in enumerate(make_batch_iterator(
            data, num_batches=args.steps)):
        params, opt_state, m = step_fn(params, opt_state, jnp.int32(step),
                                       batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            toks = args.global_batch * args.seq_len * (step + 1)
            print(f"step {step:5d}  loss {float(m['loss']):.4f}  "
                  f"{toks/(time.time()-t0):,.0f} tok/s")
        if ckpt and step and step % 100 == 0:
            ckpt.save(step, {"params": params})
    print(f"done: final loss {float(m['loss']):.4f} "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
