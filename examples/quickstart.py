"""Quickstart: the paper's pipeline end to end in ~a minute on CPU.

1. spectrally cluster synthetic client weight-embeddings (Algorithm I),
2. run three federated communication rounds with DQRE-SCnet selection,
3. validate a Pallas kernel against its jnp oracle.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np


def demo_spectral_clustering():
    from repro.core import spectral_cluster, eigengap_k, affinity_matrix, \
        spectral_embedding
    print("== 1. Spectral clustering (Algorithm I) ==")
    rng = np.random.default_rng(0)
    # three synthetic client groups in weight-embedding space
    x = np.concatenate([rng.normal(size=(20, 2)) + c
                        for c in ([0, 0], [8, 0], [4, 7])]).astype(np.float32)
    assign, _, evals = spectral_cluster(jax.random.PRNGKey(0),
                                        jnp.asarray(x), 3)
    k_hat = int(eigengap_k(evals))
    print(f"  clusters found sizes: {np.bincount(np.asarray(assign))}, "
          f"eigengap suggests k={k_hat}")


def demo_federated_rounds():
    from repro.fed import FederatedRunner, RunnerConfig
    print("== 2. Federated rounds with DQRE-SCnet selection ==")
    cfg = RunnerConfig(dataset="mnist", num_clients=12, clients_per_round=4,
                       sigma=0.8, local_steps=6, batch_size=16,
                       train_size=1500, eval_size=256, policy="dqre_sc",
                       num_clusters=3, embed_dim=4, seed=0)
    runner = FederatedRunner(cfg)
    for _ in range(3):
        res = runner.run_round()
        print(f"  round {res.round_idx}: acc={res.accuracy:.3f} "
              f"reward={res.reward:+.3f} cohort={sorted(res.selected.tolist())}")


def demo_kernel_validation():
    from repro.kernels import ops, ref
    print("== 3. Pallas kernel vs jnp oracle (interpret mode on CPU) ==")
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
    err = float(jnp.abs(ops.rbf_affinity(x, 0.5, block_m=32, block_n=32)
                        - ref.rbf_affinity_ref(x, 0.5)).max())
    print(f"  affinity kernel max |err| = {err:.2e}")


if __name__ == "__main__":
    demo_spectral_clustering()
    demo_federated_rounds()
    demo_kernel_validation()
    print("quickstart OK")
