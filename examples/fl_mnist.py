"""End-to-end paper reproduction driver (Tables 2/3 workflow).

Runs all four selection policies on one dataset/sigma with identical
seeds and reports rounds-to-target + final metrics — the paper's core
experiment.  Scale knobs default to CPU-friendly values.

  PYTHONPATH=src python examples/fl_mnist.py --dataset mnist --sigma 0.8 \
      --rounds 20
"""

import argparse
import json
import os
import sys

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "fashion_mnist", "cifar10"])
    ap.add_argument("--sigma", type=float, default=0.8)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--cohort", type=int, default=5)
    ap.add_argument("--target", type=float, default=None)
    ap.add_argument("--train-size", type=int, default=2500)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/fl")
    args = ap.parse_args()

    from repro.fed import FederatedRunner, RunnerConfig

    target = args.target if args.target is not None else \
        {"mnist": 0.9, "fashion_mnist": 0.8, "cifar10": 0.6}[args.dataset]

    results = {}
    for policy in ["fedavg", "kcenter", "favor", "dqre_sc"]:
        cfg = RunnerConfig(dataset=args.dataset, policy=policy,
                           sigma=args.sigma, num_clients=args.clients,
                           clients_per_round=args.cohort,
                           target_accuracy=target, seed=args.seed,
                           train_size=args.train_size, eval_size=512,
                           local_steps=8, batch_size=16, embed_dim=8,
                           num_clusters=max(2, args.cohort - 1))
        runner = FederatedRunner(cfg)
        runner.run(args.rounds, stop_at_target=True)
        rounds = runner.rounds_to_accuracy()
        final = runner.history[-1].accuracy
        results[policy] = {
            "rounds_to_target": rounds,
            "final_accuracy": final,
            "curve": [h.accuracy for h in runner.history],
            "metrics": runner.final_metrics(),
        }
        print(f"{policy:10s}: rounds_to_{target:.2f} = "
              f"{rounds if rounds else f'>{args.rounds}'}  "
              f"final_acc = {final:.4f}")

    base = results["fedavg"]["rounds_to_target"] or args.rounds
    ours = results["dqre_sc"]["rounds_to_target"] or args.rounds
    print(f"\ncommunication-round reduction vs FedAvg: "
          f"{100 * (1 - ours / base):.0f}%  "
          f"(paper reports 51/25/44% on real MNIST/FMNIST/CIFAR-10)")

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out,
                        f"{args.dataset}_sigma{args.sigma}_seed{args.seed}.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
