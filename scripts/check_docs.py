"""CI docs check: every ```python snippet in the docs must run.

Extracts fenced ```python blocks from README.md and docs/*.md and
executes each in a fresh namespace (shared per file, so a later block
can build on an earlier one's imports/variables).  Blocks that are
illustrative-only can opt out with a first line of ``# doc-skip``.

  PYTHONPATH=src python scripts/check_docs.py

Exit status is nonzero on the first failing block; the failing file and
block index are printed with the traceback.  tests/test_docs.py runs the
same check inside the tier-1 suite.
"""

from __future__ import annotations

import pathlib
import re
import sys
import traceback

REPO = pathlib.Path(__file__).resolve().parent.parent
_BLOCK = re.compile(r"```python\n(.*?)```", re.S)


def doc_files() -> list:
    """README.md + every markdown file under docs/."""
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def run_file(path: pathlib.Path) -> int:
    """Exec every non-skipped python block of one file; returns count."""
    namespace = {"__name__": f"__docs_{path.stem}__"}
    ran = 0
    for i, block in enumerate(_BLOCK.findall(path.read_text())):
        if block.lstrip().startswith("# doc-skip"):
            continue
        code = compile(block, f"{path.name}:block{i}", "exec")
        exec(code, namespace)          # noqa: S102 — that's the point
        ran += 1
    return ran


def main() -> int:
    failures = 0
    for path in doc_files():
        try:
            ran = run_file(path)
        except Exception:
            print(f"FAIL {path.relative_to(REPO)}")
            traceback.print_exc()
            failures += 1
        else:
            print(f"ok   {path.relative_to(REPO)} ({ran} snippets)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
