#!/usr/bin/env python
"""repro-lint CLI shim: runs without an install or PYTHONPATH.

    python scripts/lint.py --check          # CI gate
    python scripts/lint.py --list-rules
    python scripts/lint.py --update-baseline

Equivalent to ``python -m repro.analysis`` / the ``repro-lint`` entry
point; see docs/ANALYSIS.md.
"""

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.analysis.runner import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
